"""Auto-mode benchmark: measured auto picks vs fixed kernel backends.

For each focus suite matrix and (sched, comm) mode, times every fixed kernel
backend candidate once, then lets the session API's auto mode (probe solves
on) pick one. Emitted rows (per suite x mode):

* ``auto/<matrix>/<sched>-<comm>``           — the auto pick's bench time.
  Auto selects one of the fixed candidates, so its time IS that candidate's
  single measurement (re-timing the same compiled program would only add
  CI-runner noise, not information; all timings go through
  ``solve_blocks`` on pre-padded arrays, the same unit ``bench_tasks``
  uses). Derived carries the chosen backend, the probe overhead, the
  fixed-backend spread, ``not_worse_than_slowest_fixed`` (the acceptance
  predicate — true by construction of the measurement, kept as the
  machine-readable acceptance record) and ``picked_best`` (the falsifiable
  signal: did the probe ranking agree with the bench measurement?).
* ``auto/<matrix>/<sched>-<comm>/fixed-<k>`` — each fixed backend's time.
* ``auto/cache_hit_rate``                    — the shared context's cache hit
  rate across the whole sweep (us_per_call pinned to 0 so the perf gate
  never keys on it; the rate rides in the derived column).

In fast (CI ``--quick``) mode this bench also emits the
``kernel/<matrix>/{fused,switch}`` pair from its levelset-zerocopy cell —
the rows ``compare.py``'s fused-ratio gate watches — because their usual
producer (``bench_tasks``) only runs in full mode. Full runs leave those
rows to ``bench_tasks`` (same plan config) to avoid duplicate names.

All cells share ONE :class:`repro.api.SpTRSVContext`, so the sweep also
exercises the analyse-once cache across modes (same pattern, many options).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_scale, emit, time_call
from repro import compat
from repro.api import PlanOptions, SpTRSVContext
from repro.api.autotune import kernel_candidates
from repro.kernels import ops
from repro.sparse.suite import table1_suite

MODES = (("levelset", "zerocopy"), ("syncfree", "zerocopy"),
         ("levelset", "unified"))


def main() -> None:
    import jax
    import os

    fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
    focus = ("dc2",) if fast else ("dc2", "pkustk14")
    modes = MODES[:2] if fast else MODES
    D = min(4, len(jax.devices()))
    mesh = compat.make_mesh((D,), ("x",), devices=jax.devices()[:D])
    ctx = SpTRSVContext(mesh=mesh)
    fixed_backends = kernel_candidates()  # what auto's kernel axis enumerates
    for entry in [e for e in table1_suite(bench_scale()) if e.name in focus]:
        a = entry.build()
        b = np.random.default_rng(0).uniform(-1, 1, a.n)
        b_blocks = None
        for sched, comm in modes:
            times = {}
            # syncfree defines fused_streamed == fused (tune() dedups the
            # same pair) — don't pay a duplicate compile + timing for it
            cell_backends = [kb for kb in fixed_backends
                             if not (sched == "syncfree" and kb == "fused_streamed")]
            for kb in cell_backends:
                opts = PlanOptions(block_size=16, sched=sched, comm=comm,
                                   kernel=kb)
                h = ctx.analyse(a, opts)
                if b_blocks is None:
                    import jax.numpy as jnp

                    from repro.core.blocking import pad_rhs

                    b_blocks = jnp.asarray(pad_rhs(b, h.bs))
                ctx.solve(h, b)  # register the solve in the session counters
                times[kb] = time_call(ctx.executor(h).solve_blocks, b_blocks)
            auto_opts = PlanOptions(block_size=16, sched=sched, comm=comm,
                                    kernel="auto", probe_solves=3)
            h = ctx.analyse(a, auto_opts)
            dec = h.auto
            chosen = dec.chosen[2]
            t_auto = times[chosen]  # one measurement per compiled program
            worst = max(times.values())
            best = min(times.values())
            mode_tag = "interpret" if ops.interpret_mode() else "compiled"
            fixed = ",".join(f"{k}:{v:.0f}" for k, v in times.items())
            derived = (f"chosen={chosen};mode={dec.mode};"
                       f"probe_overhead_us={dec.probe_overhead_us:.0f};"
                       f"worst_fixed_us={worst:.1f};best_fixed_us={best:.1f};"
                       f"fixed={fixed};fused_mode={mode_tag};"
                       f"not_worse_than_slowest_fixed={t_auto <= worst};"
                       f"picked_best={t_auto == best}")
            emit(f"auto/{entry.name}/{sched}-{comm}", t_auto, derived)
            for kb, t in times.items():
                emit(f"auto/{entry.name}/{sched}-{comm}/fixed-{kb}", t,
                     f"kernel={kb}")
            if fast and (sched, comm) == ("levelset", "zerocopy"):
                # quick CI runs skip bench_tasks, the usual producer of the
                # rows the fused-ratio gate watches — emit them here (same
                # solve_blocks measurement unit as bench_tasks) so the gate
                # has data in every CI run
                switch_kb = next(k for k in times if k not in ops.FUSED_BACKENDS)
                emit(f"kernel/{entry.name}/switch", times[switch_kb],
                     f"kernel={switch_kb};fused_mode={mode_tag}")
                emit(f"kernel/{entry.name}/fused", times["fused"],
                     f"kernel=fused;fused_mode={mode_tag}")
    st = ctx.stats()
    emit("auto/cache_hit_rate", 0.0,
         f"hit_rate={st['cache_hit_rate']:.3f};analyses={st.get('analyses', 0)};"
         f"solves={st.get('solves', 0)};"
         f"solve_hits={st.get('solve_cache_hits', 0)}")


if __name__ == "__main__":
    main()
