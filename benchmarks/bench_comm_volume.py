"""Paper Fig. 3 analogue: communication volume per solve, unified vs zerocopy.

The paper measures page faults from UM thrashing; the structural cause is
cut-oblivious dense traffic. We report the predicted collective payload per
solve (bytes) for 2/4/8 devices — no devices needed (plan-level analysis).
Derived: volume ratio unified/zerocopy (the thrashing-elimination factor).

The model reports the *executed* packed payload: each boundary row is pulled
once at its level's bucket width (no global pad-to-max sentinel slots), and
every single-device plan reports exactly 0 bytes — asserted below per entry.
"""
from __future__ import annotations

from benchmarks.common import bench_scale, emit
from repro.core import SolverConfig, build_plan
from repro.sparse.suite import table1_suite


def main() -> None:
    for entry in table1_suite(bench_scale()):
        a = entry.build()
        # pad-slot bugfix regression: no devices -> no collectives -> 0 bytes
        for sched in ("levelset", "syncfree"):
            for comm in ("zerocopy", "unified"):
                p1 = build_plan(a, 1, SolverConfig(block_size=16, comm=comm, sched=sched))
                assert p1.comm_bytes_per_solve == 0, (
                    entry.name, sched, comm, p1.comm_bytes_per_solve)
        for D in (2, 4, 8):
            un = build_plan(a, D, SolverConfig(block_size=16, comm="unified"))
            zc = build_plan(a, D, SolverConfig(block_size=16, comm="zerocopy",
                                               partition="taskpool"))
            # volume model = executed packed payload: every boundary row pulled
            # once (bucket slack included, pad-to-max sentinel slots gone)
            assert zc.comm_bytes_per_solve >= zc.n_boundary_rows * zc.bs.B * 4
            assert (zc.comm_bytes_per_solve == 0) == (zc.n_boundary_rows == 0)
            ratio = un.comm_bytes_per_solve / max(1, zc.comm_bytes_per_solve)
            emit(f"fig3/{entry.name}/{D}dev", float(zc.comm_bytes_per_solve),
                 f"unified_over_zerocopy={ratio:.1f}")
            # malleable partition: cost-aware placement shrinks the cut itself
            ml = build_plan(a, D, SolverConfig(block_size=16, comm="zerocopy",
                                               partition="malleable"))
            ml_ratio = zc.comm_bytes_per_solve / max(1, ml.comm_bytes_per_solve)
            emit(f"fig3/{entry.name}/{D}dev/malleable",
                 float(ml.comm_bytes_per_solve),
                 f"taskpool_over_malleable={ml_ratio:.1f}")
            # corrected syncfree figure: unified/syncfree also psums the
            # in-degree counters every superstep ((B+1)-wide rows)
            un_sf = build_plan(a, D, SolverConfig(block_size=16, comm="unified",
                                                  sched="syncfree"))
            zc_sf = build_plan(a, D, SolverConfig(block_size=16, comm="zerocopy",
                                                  sched="syncfree",
                                                  partition="taskpool"))
            sf_ratio = un_sf.comm_bytes_per_solve / max(1, zc_sf.comm_bytes_per_solve)
            emit(f"fig3/{entry.name}/{D}dev/syncfree",
                 float(zc_sf.comm_bytes_per_solve),
                 f"unified_over_zerocopy={sf_ratio:.1f}")


if __name__ == "__main__":
    main()
