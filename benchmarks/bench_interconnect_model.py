"""Paper Fig. 8 analogue: interconnect sensitivity of the collective term.

DGX-1 (NVLink 64 GB/s) vs DGX-2 (NVSwitch ~100 GB/s) vs TPU v5e ICI
(~50 GB/s/link): with compute/communication overlap, the solver is
insensitive to link bandwidth once the collective term is below the compute
term — the paper's observation that DGX-1 and DGX-2 see the same speedup.
Derived: collective_term_us per interconnect and whether comm is hidden.
"""
from __future__ import annotations

from benchmarks.common import bench_scale, emit
from repro.core import SolverConfig, build_plan
from repro.sparse.suite import table1_suite

LINKS = {"nvlink64": 64e9, "nvswitch100": 100e9, "tpu_ici50": 50e9}
TRSV_FLOPS_PER_BLOCKROW = None  # computed from plan


def main() -> None:
    for entry in table1_suite(bench_scale()):
        a = entry.build()
        plan = build_plan(a, 4, SolverConfig(block_size=16, comm="zerocopy",
                                             partition="taskpool"))
        B = plan.bs.B
        # compute term: block TRSV + tile GEMVs spread over 4 devices @197TF bf16
        flops = (plan.bs.nb * B * B + plan.bs.n_tiles * 2 * B * B) / 4
        compute_us = flops / 197e12 * 1e6
        comm_bytes = plan.comm_bytes_per_solve
        for name, bw in LINKS.items():
            comm_us = comm_bytes / bw * 1e6
            hidden = comm_us <= compute_us * (plan.n_levels - 1) / max(1, plan.n_levels)
            emit(f"fig8/{entry.name}/{name}", comm_us,
                 f"comm_hidden_by_compute={hidden}")


if __name__ == "__main__":
    main()
