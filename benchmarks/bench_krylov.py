"""Krylov workload bench: SpTRSV as the hot path of preconditioned solves.

Sweeps (suite matrix) x (comm mode / partition strategy) x (RHS batch width)
for IC(0)-PCG on the
SPD expansion of each factor. All three distributed executables (SpMV, L
solve, L^T solve) are planned and compiled ONCE per (matrix, comm) cell and
reused for the warm-up and the timed run — so the timed figure is the paper's
amortized regime, not setup cost. Reported per cell:

* ``us_per_call``  — wall time per PCG *iteration* (one SpMV plus an L and an
  L^T distributed triangular solve over the whole RHS panel)
* derived          — iteration count, SpTRSV invocations in the timed run,
  and per-system iteration time (``us_per_iter / R``: the multi-RHS
  amortization factor)
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_scale, emit
from repro import compat
from repro.api import PlanOptions, SpTRSVContext
from repro.krylov import (
    DistributedSpMV,
    make_ic0_preconditioner,
    pcg,
    spd_lower_from_triangular,
)
from repro.sparse.suite import table1_suite

FOCUS = ("roadNet-CA", "dc2", "webbase-1M")
BATCHES = (1, 4, 16)


def main() -> None:
    import jax

    D = len(jax.devices())
    mesh = compat.make_mesh((D,), ("x",), devices=jax.devices()[:D])
    for entry in [e for e in table1_suite(bench_scale()) if e.name in FOCUS]:
        a = spd_lower_from_triangular(entry.build())
        rng = np.random.default_rng(0)
        for comm, partition in (("zerocopy", "taskpool"), ("zerocopy", "malleable"),
                                ("unified", "taskpool")):
            opts = PlanOptions(block_size=16, comm=comm, partition=partition)
            ctx = SpTRSVContext(mesh=mesh, options=opts)
            spmv = DistributedSpMV(ctx.plan(ctx.analyse(a)), mesh)
            psolve, handles = make_ic0_preconditioner(a, context=ctx)
            fwd, bwd = handles["forward"], handles["backward"]
            for R in BATCHES:
                b = rng.uniform(-1, 1, (a.n, R)) if R > 1 else rng.uniform(-1, 1, a.n)
                pcg(spmv.matvec, b, psolve=psolve, tol=1e-8)  # compile this shape
                calls0 = fwd.n_solves + bwd.n_solves
                t0 = time.perf_counter()
                res = pcg(spmv.matvec, b, psolve=psolve, tol=1e-8)
                dt = time.perf_counter() - t0
                iters = max(1, res.n_iters)
                us_iter = dt / iters * 1e6
                cell = comm if partition == "taskpool" else f"{comm}+{partition}"
                emit(
                    f"krylov/{entry.name}/{cell}/{D}dev/rhs{R}", us_iter,
                    f"iters={res.n_iters};trsv_calls="
                    f"{fwd.n_solves + bwd.n_solves - calls0};"
                    f"us_per_system_iter={us_iter / R:.1f}",
                )


if __name__ == "__main__":
    main()
