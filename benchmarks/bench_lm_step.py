"""LM substrate micro-bench: reduced-config train/decode step wall time.

Not a paper figure — sanity numbers proving the training/serving substrate
runs end-to-end on CPU for every architecture family in the pool.
"""
from __future__ import annotations

import numpy as np

from repro import compat
from benchmarks.common import emit, time_call

ARCHS = ["llama3.2-1b", "gemma2-2b", "falcon-mamba-7b", "zamba2-7b",
         "llama4-maverick-400b-a17b", "seamless-m4t-medium"]


def main() -> None:
    import jax

    from repro.configs import get_reduced
    from repro.data import SyntheticLM
    from repro.models import init_params
    from repro.train.optim import adamw_init
    from repro.train.step import make_train_step

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    for arch in ARCHS:
        cfg = get_reduced(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        data = SyntheticLM(cfg, 2, 32)
        step = make_train_step(cfg, mesh, example_params=params, example_opt=opt,
                               example_batch=data.batch(0), donate=False)
        us = time_call(lambda: step(params, opt, data.batch(0), np.int32(0)),
                       warmup=1, iters=3)
        emit(f"lm_train_step/{arch}", us, "reduced_config")


if __name__ == "__main__":
    main()
