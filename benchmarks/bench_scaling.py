"""Paper Fig. 10: strong scaling of zerocopy SpTRSV, 1..8 devices.

Normalized (derived column) to the single-device level-set solver — the
paper's cusparse_csrsv2 analogue. Total tasks fixed at 32 (paper §VI-D).
Each device count runs both the round-robin ``taskpool`` and the cost-model
``malleable`` partition (``.../malleable`` rows), and on the FUSED_FOCUS
matrices also the superstep megakernel backend (``.../fused`` rows) so the
fused-vs-switch gap is tracked across the scaling curve (on CPU the fused
rows time Pallas interpret mode — see bench_tasks for the flagged caveat).
The same focus matrices also emit ``sched/<matrix>/<D>dev/dagpart`` rows so
the merged-superstep scheduler's superstep/exchange counts are tracked per
device count (boundary cuts limit which levels may merge, so the reduction
is a function of D).
"""
from __future__ import annotations

import os

import numpy as np

from repro import compat
from benchmarks.common import bench_scale, emit, time_call
from repro.core import (DistributedSolver, SolverConfig, build_plan,
                        dispatch_stats, solve_local)
from repro.core.blocking import pad_rhs
from repro.sparse.suite import table1_suite

FOCUS = ("nlpkkt160", "Wordnet3", "chipcool0", "webbase-1M", "dc2")
FUSED_FOCUS = ("nlpkkt160", "webbase-1M")


def main() -> None:
    import functools

    import jax
    import jax.numpy as jnp

    max_d = int(os.environ.get("REPRO_BENCH_MAXDEV", "8"))
    for entry in [e for e in table1_suite(bench_scale()) if e.name in FOCUS]:
        a = entry.build()
        plan1 = build_plan(a, 1, SolverConfig(block_size=16))
        b = jnp.asarray(pad_rhs(np.random.default_rng(0).uniform(-1, 1, a.n), plan1.bs))
        single = jax.jit(functools.partial(solve_local, plan1))
        base_us = time_call(single, b)
        emit(f"fig10/{entry.name}/1dev", base_us, "speedup_vs_1dev=1.00")
        for D in (2, 4, 8):
            if D > max_d or D > len(jax.devices()):
                continue
            total_tasks = 32
            mesh = compat.make_mesh((D,), ("x",), devices=jax.devices()[:D])
            for strategy in ("taskpool", "malleable"):
                cfg = SolverConfig(block_size=16, comm="zerocopy", partition=strategy,
                                   tasks_per_device=max(1, total_tasks // D))
                solver = DistributedSolver(build_plan(a, D, cfg), mesh)
                us = time_call(solver.solve_blocks, b)
                suffix = "" if strategy == "taskpool" else f"/{strategy}"
                emit(f"fig10/{entry.name}/{D}dev{suffix}", us,
                     f"speedup_vs_1dev={base_us/us:.2f}")
            if entry.name in FUSED_FOCUS:
                for kb in ("fused", "fused_streamed"):
                    cfg = SolverConfig(block_size=16, comm="zerocopy",
                                       partition="taskpool",
                                       tasks_per_device=max(1, total_tasks // D),
                                       kernel_backend=kb)
                    solver = DistributedSolver(build_plan(a, D, cfg), mesh)
                    us = time_call(solver.solve_blocks, b)
                    emit(f"fig10/{entry.name}/{D}dev/{kb}", us,
                         f"speedup_vs_1dev={base_us/us:.2f}")
                cfg = SolverConfig(block_size=16, comm="zerocopy",
                                   partition="taskpool", sched="dagpart",
                                   tasks_per_device=max(1, total_tasks // D))
                plan = build_plan(a, D, cfg)
                ds = dispatch_stats(plan)
                solver = DistributedSolver(plan, mesh)
                us = time_call(solver.solve_blocks, b)
                emit(f"sched/{entry.name}/{D}dev/dagpart", us,
                     f"speedup_vs_1dev={base_us/us:.2f};"
                     f"supersteps={ds['supersteps']};"
                     f"supersteps_levelset={ds['supersteps_levelset']};"
                     f"launches={ds['switch_dispatches']};"
                     f"exchanges={ds['exchanges']};"
                     f"schedule_table_bytes={ds['schedule_table_bytes']}")


if __name__ == "__main__":
    main()
