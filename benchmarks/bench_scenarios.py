"""Paper Fig. 7: SpTRSV design scenarios on 4 devices.

Scenarios (exact analogues of the paper's four bars, DESIGN.md §5.2, plus the
malleable cost-model partition on top of the zero-copy exchange):
  unified            4GPU-Unified        dense all-reduce/superstep, contiguous
  unified+task       4GPU-Unified+8task  dense exchange + task-pool partition
  shmem              4GPU-Shmem          packed boundary exchange, contiguous
  zerocopy           4GPU-Zerocopy       packed exchange + task-pool (8 tasks)
  malleable          (this repo)         packed exchange + cost-model partition
  dagpart            (this repo)         zerocopy + DAG-partition merged supersteps

Derived column: speedup over `unified` (the paper's normalization). Runs
through one :class:`repro.api.SpTRSVContext` per matrix — the five scenarios
are one analysed pattern under different options (partition strategies fork
the symbolic cache; comm modes share it).
"""
from __future__ import annotations

import numpy as np

from repro import compat
from benchmarks.common import bench_scale, emit, time_call
from repro.api import PlanOptions, SpTRSVContext
from repro.core.blocking import pad_rhs
from repro.sparse.suite import table1_suite

SCENARIOS = {
    "unified": PlanOptions(block_size=16, comm="unified", partition="contiguous"),
    "unified+task": PlanOptions(block_size=16, comm="unified", partition="taskpool",
                                tasks_per_device=8),
    "shmem": PlanOptions(block_size=16, comm="zerocopy", partition="contiguous"),
    "zerocopy": PlanOptions(block_size=16, comm="zerocopy", partition="taskpool",
                            tasks_per_device=8),
    "malleable": PlanOptions(block_size=16, comm="zerocopy", partition="malleable",
                             tasks_per_device=8),
    # the quick-lane dagpart axis: merged plans stay timed (and, through the
    # context's verify hook, buildable) on every PR, not just full runs
    "dagpart": PlanOptions(block_size=16, comm="zerocopy", partition="taskpool",
                           tasks_per_device=8, sched="dagpart"),
}


def main() -> None:
    import jax
    import jax.numpy as jnp

    D = 4
    assert len(jax.devices()) >= D, "run via benchmarks.run (forces device count)"
    mesh = compat.make_mesh((D,), ("x",), devices=jax.devices()[:D])
    for entry in table1_suite(bench_scale()):
        a = entry.build()
        rng = np.random.default_rng(0)
        ctx = SpTRSVContext(mesh=mesh)
        first = ctx.analyse(a, next(iter(SCENARIOS.values())))
        b = jnp.asarray(pad_rhs(rng.uniform(-1, 1, a.n), first.bs))
        base_us = None
        for name, opts in SCENARIOS.items():
            solver = ctx.executor(ctx.analyse(a, opts))
            us = time_call(solver.solve_blocks, b)
            if name == "unified":
                base_us = us
            emit(f"fig7/{entry.name}/{name}", us, f"speedup={base_us / us:.2f}")


if __name__ == "__main__":
    main()
