"""Service-axis benchmark: solves/sec at a request mix (ISSUE 9).

A serving front end changes the unit of measurement: not the latency of one
solve but the throughput of a *request mix* — many tenants, a hot pattern
plus a cold tail, every request its own RHS. For each mix this bench stands
up a warm :class:`repro.service.SolveEngine` over a populated plan store and
times two serving disciplines over the identical request sequence:

* **batched** — the admission queue coalesces same-pattern RHS into multi-RHS
  panels (``max_batch`` wide), one compiled dispatch per panel;
* **one-by-one** — ``max_batch=1``, the no-coalescing baseline every request
  pays its own dispatch for.

Emitted rows (CSV convention ``name,us_per_call,derived``):

* ``service/<mix>`` — batched per-request time. The derived column is
  self-contained for the compare gate: ``req_per_s``, ``coalesce_width``,
  ``hit_rate`` (plan-store), ``coalesce_win`` (one-by-one us / batched us —
  the quantity ``compare.py --min-coalesce-win`` gates on the hot mix),
  ``analysis_cold_us`` vs ``analysis_warm_us`` (fresh symbolic analysis vs
  store-hydrated analyse of the hot pattern — what persistence buys a
  cold-started worker).
* ``service/<mix>/onebyone`` — the baseline per-request time.

Both disciplines run one warmup pass (compile) before the timed pass, so the
comparison is steady-state serving throughput, not trace caching.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.api import PlanOptions, SpTRSVContext
from repro.service import PlanStore, SolveEngine
from repro.sparse import suite

BLOCK = 32
MAX_BATCH = 8

# mix -> (pattern builders, request pattern-index sequence)
MIXES = {
    # every request on one hot pattern: pure coalescing
    "hot": ((lambda: suite.random_levelled(600, 24, 4.0, seed=0),),
            [0] * 32),
    # 3-pattern hot/cold mix, ~70% of traffic on pattern 0
    "mixed": ((lambda: suite.random_levelled(600, 24, 4.0, seed=0),
               lambda: suite.random_levelled(300, 12, 4.0, seed=1),
               lambda: suite.grid2d_factor(14, seed=2)),
              [0, 0, 1, 0, 0, 2, 0, 0, 1, 0, 0, 0,
               0, 2, 0, 0, 1, 0, 0, 0, 0, 0, 2, 0]),
}


def serve_pass(engine: SolveEngine, mats, mix, rhs) -> tuple[float, dict]:
    """Submit + drain the whole mix; returns (wall_s, stats delta)."""
    before = dict(engine._counters)
    t0 = time.perf_counter()
    tickets = [engine.submit(f"tenant{i % 4}", mats[p], rhs[i])
               for i, p in enumerate(mix)]
    engine.drain()
    wall = time.perf_counter() - t0
    assert all(t.done() for t in tickets)
    after = engine.stats()
    delta = {k: after.get(k, 0) - before.get(k, 0)
             for k in ("results", "batches", "coalesced_columns")}
    return wall, delta


def analysis_us(a, opts, store=None) -> float:
    """Wall time of one full analyse (+ forward plan) on a fresh session."""
    ctx = SpTRSVContext(options=opts, plan_store=store)
    t0 = time.perf_counter()
    ctx.plan(ctx.analyse(a))
    return (time.perf_counter() - t0) * 1e6


def main() -> None:
    opts = PlanOptions(block_size=BLOCK)
    rng = np.random.default_rng(0)
    for mix_name, (builders, mix) in MIXES.items():
        mats = [build() for build in builders]
        rhs = [rng.uniform(-1, 1, mats[p].n).astype(np.float32) for p in mix]
        store_root = f"/tmp/repro-bench-plans-{mix_name}"
        PlanStore(store_root)  # ensure the directory exists

        # populate the store + measure the analysis amortization directly
        cold_us = analysis_us(mats[0], opts)
        pop = SpTRSVContext(options=opts, plan_store=PlanStore(store_root))
        for m in mats:
            pop.plan(pop.analyse(m))
        warm_us = analysis_us(mats[0], opts, store=PlanStore(store_root))

        results = {}
        for label, width in (("batched", MAX_BATCH), ("onebyone", 1)):
            store = PlanStore(store_root)
            engine = SolveEngine(options=opts, plan_store=store,
                                 max_batch=width)
            serve_pass(engine, mats, mix, rhs)  # warmup: compile + load plans
            wall, delta = serve_pass(engine, mats, mix, rhs)
            assert delta["results"] == len(mix)
            results[label] = (wall, delta, store.stats)

        wall_b, delta_b, ps = results["batched"]
        wall_1, _, _ = results["onebyone"]
        us_b = wall_b * 1e6 / len(mix)
        us_1 = wall_1 * 1e6 / len(mix)
        width = delta_b["coalesced_columns"] / max(delta_b["batches"], 1)
        derived = (f"req_per_s={len(mix) / wall_b:.0f};"
                   f"solves_per_s={delta_b['batches'] / wall_b:.0f};"
                   f"coalesce_width={width:.2f};"
                   f"hit_rate={ps['hit_rate']:.2f};"
                   f"coalesce_win={us_1 / us_b:.3f};"
                   f"analysis_cold_us={cold_us:.0f};"
                   f"analysis_warm_us={warm_us:.0f};"
                   f"requests={len(mix)};batches={delta_b['batches']}")
        emit(f"service/{mix_name}", us_b, derived)
        emit(f"service/{mix_name}/onebyone", us_1,
             f"req_per_s={len(mix) / wall_1:.0f}")


if __name__ == "__main__":
    main()
