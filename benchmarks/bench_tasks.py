"""Paper Fig. 9: sensitivity to tasks-per-device (zerocopy, 4 devices).

Swept for both the paper's round-robin ``taskpool`` and the cost-model
``malleable`` partition (where ``tasks_per_device`` bounds the number of
adaptive tasks carved per level). Derived column: performance normalized to
the 4-tasks/device case of the same strategy (paper's normalization), i.e.
``t_4task / t_this``.

Also emits the ``kernel/<matrix>/{fused,switch}`` comparison: the same plan
run through the superstep megakernel (``kernel_backend="fused"``) vs the
``lax.switch`` executor, with the exact dispatch counts from
``dispatch_stats`` in the derived column — the launch-overhead claim is
measured, not asserted.

And the ``sched/<matrix>/{levelset,dagpart}`` comparison: the DAG-partition
merged-superstep scheduler vs plain levelset on the chain-skewed focus
matrices plus a synthetic long chain, with superstep / launch / exchange /
schedule-table-byte counts in the derived column. The counts are exact plan
statics (no noise floor), which is what ``benchmarks/compare.py``'s
superstep-reduction predicate gates on.
"""
from __future__ import annotations

import numpy as np

from repro import compat
from benchmarks.common import bench_scale, emit, time_call
from repro.core import DistributedSolver, SolverConfig, build_plan, dispatch_stats
from repro.core.blocking import pad_rhs
from repro.sparse.suite import table1_suite

TASKS = [1, 2, 4, 8, 16, 32]
STRATEGIES = ("taskpool", "malleable")
KERNEL_FOCUS = ("dc2", "pkustk14")  # wide + chain-skewed regimes
SCHED_FOCUS = ("dc2", "pkustk14")  # dagpart-vs-levelset comparison matrices


def main() -> None:
    import jax
    import jax.numpy as jnp

    D = 4
    mesh = compat.make_mesh((D,), ("x",), devices=jax.devices()[:D])
    suite = [e for e in table1_suite(bench_scale())
             if e.name in ("webbase-1M", "dc2", "pkustk14", "nlpkkt160", "delaunay_n20")]
    for entry in suite:
        a = entry.build()
        b = jnp.asarray(pad_rhs(np.random.default_rng(0).uniform(-1, 1, a.n),
                                build_plan(a, 1, SolverConfig(block_size=16)).bs))
        for strategy in STRATEGIES:
            results = {}
            for t in TASKS:
                cfg = SolverConfig(block_size=16, comm="zerocopy", partition=strategy,
                                   tasks_per_device=t)
                solver = DistributedSolver(build_plan(a, D, cfg), mesh)
                results[t] = time_call(solver.solve_blocks, b)
            suffix = "" if strategy == "taskpool" else f"/{strategy}"
            for t in TASKS:
                emit(f"fig9/{entry.name}/tasks{t}{suffix}", results[t],
                     f"norm_vs_4task={results[4] / results[t]:.2f}")

        # fused megakernel (resident + streaming tile store) vs lax.switch
        # executor on the same plan. On CPU the fused columns run in Pallas
        # INTERPRET mode (flagged in the derived field) — there the portable
        # signal is the dispatch-count / DMA-byte ratio, not the wall time;
        # only a TPU run times the compiled megakernels.
        if entry.name in KERNEL_FOCUS:
            from repro.kernels import ops

            times = {}
            per_kb_stats = {}
            for kb in ("reference", "fused", "fused_streamed"):
                cfg = SolverConfig(block_size=16, comm="zerocopy",
                                   partition="taskpool", tasks_per_device=8,
                                   kernel_backend=kb)
                plan = build_plan(a, D, cfg)
                per_kb_stats[kb] = dispatch_stats(plan)
                solver = DistributedSolver(plan, mesh)
                times[kb] = time_call(solver.solve_blocks, b)
            stats = per_kb_stats["fused"]
            st_stats = per_kb_stats["fused_streamed"]
            mode = "interpret" if ops.interpret_mode() else "compiled"
            derived = (f"fused_launches={stats['fused_launches']};"
                       f"switch_dispatches={stats['switch_dispatches']};"
                       f"speedup_vs_switch={times['reference'] / times['fused']:.2f};"
                       f"fused_mode={mode}")
            emit(f"kernel/{entry.name}/switch", times["reference"], derived)
            emit(f"kernel/{entry.name}/fused", times["fused"], derived)
            emit(f"kernel/{entry.name}/fused_streamed", times["fused_streamed"],
                 f"fused_launches={st_stats['fused_launches']};"
                 f"vmem_bytes={st_stats['fused_vmem_bytes']};"
                 f"resident_vmem_bytes={stats['fused_vmem_bytes']};"
                 f"dma_bytes={st_stats['stream_dma_bytes']};"
                 f"speedup_vs_resident={times['fused'] / times['fused_streamed']:.2f};"
                 f"fused_mode={mode}")

    # dagpart vs levelset: merged-superstep scheduling on chain-heavy
    # structures. Each dagpart row's derived column is self-contained (it
    # carries both its own and the levelset superstep count) so the
    # compare.py reduction gate needs no row joins.
    from repro.sparse import suite as sparse_suite

    sched_cases = [(e.name, e.build(), "taskpool") for e in suite
                   if e.name in SCHED_FOCUS]
    # the chain keeps a 1024-row floor (so the merge regime survives
    # REPRO_BENCH_SCALE) and uses the contiguous partition — a chain has no
    # level parallelism, and round-robin dealing would put every dependency
    # across a device boundary, where no merge is legal
    sched_cases.append(
        ("chain", sparse_suite.chain(max(1024, int(4000 * bench_scale()))),
         "contiguous"))
    for name, a, partition in sched_cases:
        b = jnp.asarray(pad_rhs(np.random.default_rng(0).uniform(-1, 1, a.n),
                                build_plan(a, 1, SolverConfig(block_size=16)).bs))
        stats, times = {}, {}
        for sched in ("levelset", "dagpart"):
            cfg = SolverConfig(block_size=16, comm="zerocopy",
                               partition=partition, tasks_per_device=8,
                               sched=sched)
            plan = build_plan(a, D, cfg)
            stats[sched] = dispatch_stats(plan)
            solver = DistributedSolver(plan, mesh)
            times[sched] = time_call(solver.solve_blocks, b)
        for sched in ("levelset", "dagpart"):
            ds = stats[sched]
            emit(f"sched/{name}/{sched}", times[sched],
                 f"supersteps={ds['supersteps']};"
                 f"supersteps_levelset={ds['supersteps_levelset']};"
                 f"launches={ds['switch_dispatches']};"
                 f"fused_launches={ds['fused_launches']};"
                 f"exchanges={ds['exchanges']};"
                 f"schedule_table_bytes={ds['schedule_table_bytes']};"
                 f"speedup_vs_levelset="
                 f"{times['levelset'] / times[sched]:.2f}")


if __name__ == "__main__":
    main()
