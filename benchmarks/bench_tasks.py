"""Paper Fig. 9: sensitivity to tasks-per-device (zerocopy, 4 devices).

Swept for both the paper's round-robin ``taskpool`` and the cost-model
``malleable`` partition (where ``tasks_per_device`` bounds the number of
adaptive tasks carved per level). Derived column: performance normalized to
the 4-tasks/device case of the same strategy (paper's normalization), i.e.
``t_4task / t_this``.
"""
from __future__ import annotations

import numpy as np

from repro import compat
from benchmarks.common import bench_scale, emit, time_call
from repro.core import DistributedSolver, SolverConfig, build_plan
from repro.core.blocking import pad_rhs
from repro.sparse.suite import table1_suite

TASKS = [1, 2, 4, 8, 16, 32]
STRATEGIES = ("taskpool", "malleable")


def main() -> None:
    import jax
    import jax.numpy as jnp

    D = 4
    mesh = compat.make_mesh((D,), ("x",), devices=jax.devices()[:D])
    suite = [e for e in table1_suite(bench_scale())
             if e.name in ("webbase-1M", "dc2", "pkustk14", "nlpkkt160", "delaunay_n20")]
    for entry in suite:
        a = entry.build()
        b = jnp.asarray(pad_rhs(np.random.default_rng(0).uniform(-1, 1, a.n),
                                build_plan(a, 1, SolverConfig(block_size=16)).bs))
        for strategy in STRATEGIES:
            results = {}
            for t in TASKS:
                cfg = SolverConfig(block_size=16, comm="zerocopy", partition=strategy,
                                   tasks_per_device=t)
                solver = DistributedSolver(build_plan(a, D, cfg), mesh)
                results[t] = time_call(solver.solve_blocks, b)
            suffix = "" if strategy == "taskpool" else f"/{strategy}"
            for t in TASKS:
                emit(f"fig9/{entry.name}/tasks{t}{suffix}", results[t],
                     f"norm_vs_4task={results[4] / results[t]:.2f}")


if __name__ == "__main__":
    main()
