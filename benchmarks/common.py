"""Benchmark utilities: timing, device-count subprocesses, CSV convention.

Every bench prints ``name,us_per_call,derived`` lines (one per measurement);
``derived`` carries the paper-figure quantity (speedup, normalized perf, ...).
Multi-device benches re-exec themselves in a subprocess with
``--xla_force_host_platform_device_count`` so the parent keeps 1 device.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time


def bench_scale() -> float:
    return float(os.environ.get("REPRO_BENCH_SCALE", "0.1"))


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_with_devices(module: str, n_devices: int, extra_env: dict | None = None) -> str:
    """Run ``python -m <module>`` with N forced host devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.join(os.path.dirname(__file__), ".."),
         env.get("PYTHONPATH", "")]
    )
    env.update(extra_env or {})
    out = subprocess.run(
        [sys.executable, "-m", module], env=env, capture_output=True, text=True,
        timeout=3000,
    )
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-4000:])
        raise RuntimeError(f"{module} failed")
    return out.stdout


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
