"""Cross-PR perf-trajectory gate (ROADMAP "Perf trajectory").

Compares a new bench JSON row map (written by ``benchmarks/run.py``) against a
*window* of previous ``bench-trajectory`` artifacts and fails when any row
regresses by more than the threshold against the window's per-row median:

    python benchmarks/compare.py PREV1.json [PREV2.json ...] NEW.json \
        [--max-regression 0.25] [--max-fused-regression 0.25]

The last path is the new run; every earlier path joins the baseline window
(a single predecessor degenerates to the old two-file comparison). Medians
over an N-run window keep one noisy CI run from poisoning the gate in either
direction.

Rows are matched on their full ``suite/mode`` name. Sub-threshold timings
(default < 50us) are skipped — at that scale CI-runner jitter swamps any real
signal. Rows present in only one side are listed informationally (new
benchmarks appear, retired ones disappear) but never fail the gate.

A dedicated gate watches the fused-vs-switch executor ratio: for every
``kernel/<matrix>/fused`` row with a ``kernel/<matrix>/switch`` sibling, the
``fused/switch`` time ratio must not regress more than
``--max-fused-regression`` vs the window's median ratio — the megakernel's
advantage is a first-class trajectory metric, not just two independent rows.

A second dedicated gate watches the DAG-partition scheduler: every
``sched/<matrix>/dagpart`` row on a chain-heavy matrix must report a
superstep reduction (``supersteps_levelset / supersteps``, parsed from the
row's self-contained derived column) of at least
``--min-superstep-reduction`` (default 2x). These are exact plan statics —
no noise floor, no window median: a merge-heuristic regression that stops
collapsing the chain fails the *new* run outright.

A third dedicated gate watches the serving layer: every hot-mix
``service/<mix>`` row must report a ``coalesce_win`` (one-by-one per-request
time / batched per-request time, self-contained in the derived column) of at
least ``--min-coalesce-win`` (default 1.0) — batched multi-RHS serving that
stops beating one-by-one dispatch is a queue/panel regression, gated on the
new run alone.
"""
from __future__ import annotations

import argparse
import json
import sys

MIN_US = 50.0  # ignore rows faster than this: pure scheduler noise on CI

# matrices whose level structure is dominated by long narrow chains — the
# regime the dagpart merge pass exists for; its reduction is gated on these
CHAIN_HEAVY = ("chain",)

# request mixes where coalescing has same-pattern traffic to batch — the
# regime the serving queue exists for; its throughput win is gated on these
HOT_MIXES = ("hot", "mixed")


def load_rows(path: str) -> dict:
    """Timing rows only; ``_``-prefixed keys (``_provenance``, ``_metrics``)
    are metadata written by run.py and never participate in gating."""
    with open(path) as f:
        rows = json.load(f)
    return {k: float(v.get("us_per_call", 0.0)) for k, v in rows.items()
            if not k.startswith("_")}


def load_provenance(path: str) -> dict:
    """The run's ``_provenance`` block ({} for pre-provenance bench files)."""
    with open(path) as f:
        rows = json.load(f)
    prov = rows.get("_provenance")
    return prov if isinstance(prov, dict) else {}


def provenance_note(old_path: str, new_path: str) -> str:
    """One line contrasting the environments of two runs — shown next to gate
    failures so a regression caused by a jax upgrade or a different device
    fleet is recognizable at a glance. Empty when nothing differs (or no
    provenance was recorded)."""
    old, new = load_provenance(old_path), load_provenance(new_path)
    if not old or not new:
        return ""
    diffs = []
    for key in ("jax_version", "platform", "device_kind", "device_count",
                "git_sha"):
        ov, nv = old.get(key), new.get(key)
        if ov != nv and (ov or nv):
            diffs.append(f"{key}: {ov!r} -> {nv!r}")
    return "; ".join(diffs)


def parse_derived(derived: str) -> dict:
    """``k=v;...`` derived column -> dict of raw string values."""
    out = {}
    for part in str(derived).split(";"):
        key, sep, val = part.partition("=")
        if sep:
            out[key.strip()] = val.strip()
    return out


def superstep_reductions(path: str) -> dict:
    """``matrix -> supersteps_levelset / supersteps`` for every
    ``sched/<matrix>/dagpart`` row whose derived column carries both counts
    (each row is self-contained, so no join against the levelset row)."""
    with open(path) as f:
        rows = json.load(f)
    out = {}
    for name, row in rows.items():
        if name.startswith("_") or not isinstance(row, dict):
            continue
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "sched" or parts[2] != "dagpart":
            continue
        d = parse_derived(row.get("derived", ""))
        try:
            steps = float(d["supersteps"])
            base = float(d["supersteps_levelset"])
        except (KeyError, ValueError):
            continue
        if steps > 0:
            out[parts[1]] = base / steps
    return out


def gate_superstep_reduction(path: str, min_reduction: float) -> list:
    """``(matrix, reduction)`` failures: chain-heavy dagpart rows in the new
    run whose merged plan keeps too many supersteps."""
    return [(m, r) for m, r in sorted(superstep_reductions(path).items())
            if m in CHAIN_HEAVY and r < min_reduction]


def coalesce_wins(path: str) -> dict:
    """``mix -> coalesce_win`` for every ``service/<mix>`` row whose derived
    column carries the batched-vs-one-by-one ratio (each row is
    self-contained: no join against the ``/onebyone`` sibling)."""
    with open(path) as f:
        rows = json.load(f)
    out = {}
    for name, row in rows.items():
        if name.startswith("_") or not isinstance(row, dict):
            continue
        parts = name.split("/")
        if len(parts) != 2 or parts[0] != "service":
            continue
        d = parse_derived(row.get("derived", ""))
        try:
            out[parts[1]] = float(d["coalesce_win"])
        except (KeyError, ValueError):
            continue
    return out


def gate_coalesce_win(path: str, min_win: float) -> list:
    """``(mix, win)`` failures: hot-mix service rows in the new run where
    batched serving no longer beats one-by-one by the required factor."""
    return [(m, w) for m, w in sorted(coalesce_wins(path).items())
            if m in HOT_MIXES and w < min_win]


def _median(vals: list) -> float:
    vals = sorted(vals)
    if not vals:
        return 0.0
    mid = len(vals) // 2
    return vals[mid] if len(vals) % 2 else 0.5 * (vals[mid - 1] + vals[mid])


def window_median(window: list, name: str) -> float:
    """Median of a row's positive timings across the window (0.0 if unseen)."""
    return _median([r[name] for r in window if r.get(name, 0.0) > 0.0])


def compare(window: list, new: dict, max_regression: float):
    """Returns (regressions, improvements, skipped, zeroed) row lists.

    ``window`` is a list of row maps (oldest first is fine — order is
    irrelevant, the baseline is the per-row median).
    """
    shared = sorted(set().union(*window) & set(new)) if window else []
    regressions, improvements, skipped, zeroed = [], [], [], []
    for name in shared:
        old_us, new_us = window_median(window, name), new[name]
        if new_us <= 0.0 < old_us:
            # a previously-timed row now reports 0: the bench likely broke;
            # surface it loudly instead of burying it in the skip count
            zeroed.append((name, old_us))
            continue
        if old_us < MIN_US and new_us < MIN_US:
            skipped.append(name)  # both sub-threshold: pure scheduler noise
            continue
        if old_us <= 0.0:
            skipped.append(name)
            continue
        ratio = new_us / old_us
        if ratio > 1.0 + max_regression:
            regressions.append((name, old_us, new_us, ratio))
        elif ratio < 1.0 - max_regression:
            improvements.append((name, old_us, new_us, ratio))
    return regressions, improvements, skipped, zeroed


def fused_ratios(rows: dict) -> dict:
    """``matrix -> fused_us / switch_us`` for every kernel/<m>/{fused,switch}
    pair with meaningfully-timed members (both above the noise floor)."""
    out = {}
    for name, fused_us in rows.items():
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "kernel" or parts[2] != "fused":
            continue
        switch_us = rows.get(f"kernel/{parts[1]}/switch", 0.0)
        if fused_us >= MIN_US and switch_us >= MIN_US:
            out[parts[1]] = fused_us / switch_us
    return out


def compare_fused(window: list, new: dict, max_regression: float):
    """Gate the fused-vs-switch ratio against the window's median ratio."""
    new_r = fused_ratios(new)
    win_r = [fused_ratios(rows) for rows in window]
    regressions = []
    for matrix, ratio in sorted(new_r.items()):
        base = _median([r[matrix] for r in win_r if matrix in r])
        if base <= 0.0:
            continue
        if ratio > base * (1.0 + max_regression):
            regressions.append((matrix, base, ratio))
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+",
                    help="previous bench JSONs (the window) then the new one")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="fail when new > window-median * (1 + this) on any row")
    ap.add_argument("--max-fused-regression", type=float, default=0.25,
                    help="fail when the fused/switch time ratio grows by more "
                         "than this vs the window median")
    ap.add_argument("--min-superstep-reduction", type=float, default=2.0,
                    help="fail when a chain-heavy sched/<m>/dagpart row in "
                         "the new run reduces supersteps by less than this "
                         "factor vs levelset")
    ap.add_argument("--min-coalesce-win", type=float, default=1.0,
                    help="fail when a hot-mix service/<mix> row in the new "
                         "run reports batched throughput less than this "
                         "factor over one-by-one serving")
    args = ap.parse_args(argv)
    if len(args.files) < 2:
        ap.error("need at least one previous and one new JSON")
    window = [load_rows(p) for p in args.files[:-1]]
    new = load_rows(args.files[-1])
    regressions, improvements, skipped, zeroed = compare(
        window, new, args.max_regression)
    fused_regr = compare_fused(window, new, args.max_fused_regression)
    sched_regr = gate_superstep_reduction(args.files[-1],
                                          args.min_superstep_reduction)
    serve_regr = gate_coalesce_win(args.files[-1], args.min_coalesce_win)

    seen_prev = set().union(*window)
    only_prev = sorted(seen_prev - set(new))
    only_new = sorted(set(new) - seen_prev)
    print(f"[compare] window of {len(window)} run(s), "
          f"{len(seen_prev & set(new))} shared rows "
          f"({len(skipped)} below {MIN_US:.0f}us noise floor), "
          f"{len(only_prev)} retired, {len(only_new)} new")
    for name, old_us in zeroed:
        print(f"[compare] WARNING {name}: window median {old_us:.0f}us, now "
              f"reports 0 — benchmark broken or no longer timed")
    for name, old_us, new_us, ratio in improvements:
        print(f"[compare] improved  {name}: {old_us:.0f} -> {new_us:.0f}us "
              f"({ratio:.2f}x)")
    for name, old_us, new_us, ratio in regressions:
        print(f"[compare] REGRESSED {name}: {old_us:.0f} -> {new_us:.0f}us "
              f"({ratio:.2f}x > {1 + args.max_regression:.2f}x)")
    for matrix, base, ratio in fused_regr:
        print(f"[compare] FUSED-RATIO REGRESSED kernel/{matrix}: "
              f"fused/switch {base:.2f} -> {ratio:.2f} "
              f"(>{1 + args.max_fused_regression:.2f}x)")
    for matrix, reduction in sched_regr:
        print(f"[compare] SUPERSTEP REDUCTION FAILED sched/{matrix}/dagpart: "
              f"{reduction:.2f}x < required "
              f"{args.min_superstep_reduction:.2f}x")
    for mix, win in serve_regr:
        print(f"[compare] COALESCE WIN FAILED service/{mix}: batched is "
              f"{win:.2f}x one-by-one < required "
              f"{args.min_coalesce_win:.2f}x")
    if regressions or fused_regr or sched_regr or serve_regr:
        note = provenance_note(args.files[0], args.files[-1])
        if note:
            print(f"[compare] provenance drift (informational): {note}")
        print(f"[compare] FAIL: {len(regressions)} row(s) regressed "
              f">{args.max_regression:.0%}, {len(fused_regr)} fused-ratio "
              f"regression(s), {len(sched_regr)} superstep-reduction "
              f"failure(s), {len(serve_regr)} coalesce-win failure(s)")
        return 1
    print("[compare] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
