"""Cross-PR perf-trajectory gate (ROADMAP "Perf trajectory").

Compares two bench JSON row maps (written by ``benchmarks/run.py``) and fails
when any row shared by both regresses by more than the threshold:

    python benchmarks/compare.py PREV.json NEW.json [--max-regression 0.25]

Rows are matched on their full ``suite/mode`` name. Sub-threshold timings
(default < 50us) are skipped — at that scale CI-runner jitter swamps any real
signal. Rows present in only one file are listed informationally (new
benchmarks appear, retired ones disappear) but never fail the gate.
"""
from __future__ import annotations

import argparse
import json
import sys

MIN_US = 50.0  # ignore rows faster than this: pure scheduler noise on CI


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {k: float(v.get("us_per_call", 0.0)) for k, v in rows.items()}


def compare(prev: dict, new: dict, max_regression: float):
    """Returns (regressions, improvements, skipped, zeroed) row lists."""
    regressions, improvements, skipped, zeroed = [], [], [], []
    for name in sorted(set(prev) & set(new)):
        old_us, new_us = prev[name], new[name]
        if new_us <= 0.0 < old_us:
            # a previously-timed row now reports 0: the bench likely broke;
            # surface it loudly instead of burying it in the skip count
            zeroed.append((name, old_us))
            continue
        if old_us < MIN_US and new_us < MIN_US:
            skipped.append(name)  # both sub-threshold: pure scheduler noise
            continue
        if old_us <= 0.0:
            skipped.append(name)
            continue
        ratio = new_us / old_us
        if ratio > 1.0 + max_regression:
            regressions.append((name, old_us, new_us, ratio))
        elif ratio < 1.0 - max_regression:
            improvements.append((name, old_us, new_us, ratio))
    return regressions, improvements, skipped, zeroed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("prev")
    ap.add_argument("new")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="fail when new > prev * (1 + this) on any shared row")
    args = ap.parse_args(argv)
    prev, new = load_rows(args.prev), load_rows(args.new)
    regressions, improvements, skipped, zeroed = compare(
        prev, new, args.max_regression)

    only_prev = sorted(set(prev) - set(new))
    only_new = sorted(set(new) - set(prev))
    print(f"[compare] {len(set(prev) & set(new))} shared rows "
          f"({len(skipped)} below {MIN_US:.0f}us noise floor), "
          f"{len(only_prev)} retired, {len(only_new)} new")
    for name, old_us in zeroed:
        print(f"[compare] WARNING {name}: previously {old_us:.0f}us, now "
              f"reports 0 — benchmark broken or no longer timed")
    for name, old_us, new_us, ratio in improvements:
        print(f"[compare] improved  {name}: {old_us:.0f} -> {new_us:.0f}us "
              f"({ratio:.2f}x)")
    for name, old_us, new_us, ratio in regressions:
        print(f"[compare] REGRESSED {name}: {old_us:.0f} -> {new_us:.0f}us "
              f"({ratio:.2f}x > {1 + args.max_regression:.2f}x)")
    if regressions:
        print(f"[compare] FAIL: {len(regressions)} row(s) regressed "
              f">{args.max_regression:.0%}")
        return 1
    print("[compare] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
