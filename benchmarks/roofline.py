"""§Roofline: derive the three roofline terms from dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. ``cost_analysis()`` on the partitioned executable is per-device;
collective bytes come from the HLO parse in repro.launch.dryrun.

Also reports MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)
and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs × n_devices) — remat and
dispatch overheads show up here.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

# XLA CPU's cost model counts multiply and add separately: a (N,K)x(K,M) dot
# reports 2·N·M·K — the same convention as 6ND. Calibrated by lowering a pure
# 1024³ matmul (tests/test_roofline.py). No correction needed.
FMA_FACTOR = 1.0


def load_cells(out_dir: str = "experiments/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def wire_bytes(coll: dict, ring: int = 16) -> float:
    """Payload -> ring wire bytes: all-reduce moves 2(n-1)/n of its payload,
    all-gather/reduce-scatter/all-to-all (n-1)/n (n = ring size, model axis)."""
    f_ar = 2.0 * (ring - 1) / ring
    f_other = (ring - 1) / ring
    total = 0.0
    for k, v in coll.items():
        if k == "total":
            continue
        total += v * (f_ar if k == "all-reduce" else f_other)
    return total


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    flops_dev = rec["flops_per_device"] * FMA_FACTOR
    bytes_dev = rec["bytes_per_device"]
    coll_dev = wire_bytes(rec["collectives"]["bytes"])
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    useful = rec["model_flops"] / max(1.0, flops_dev * n)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bottleneck": dom[0],
        "bound_s": dom[1],
        "model_flops": rec["model_flops"],
        "useful_flops_ratio": useful,
        "roofline_fraction": t_c / max(t_c, t_m, t_x),
        "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
    }


def main() -> None:
    rows = [r for r in (roofline_row(c) for c in load_cells()) if r]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    print("name,us_per_call,derived")
    for r in rows:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        derived = (
            f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.2f};"
            f"cmp={r['compute_s']*1e3:.1f}ms;mem={r['memory_s']*1e3:.1f}ms;"
            f"coll={r['collective_s']*1e3:.1f}ms;useful={r['useful_flops_ratio']:.2f}"
        )
        print(f"{name},{r['bound_s']*1e6:.1f},{derived}")


if __name__ == "__main__":
    main()
