"""Benchmark driver: one section per paper table/figure. CSV: name,us_per_call,derived.

  fig3   communication volume, unified vs zerocopy (paper Fig. 3 analogue)
  fig7   design-scenario speedups on 4 devices      (paper Fig. 7)
  fig8   interconnect sensitivity model             (paper Fig. 8)
  fig9   tasks-per-device sensitivity               (paper Fig. 9)
  fig10  strong scaling 1..8 devices                (paper Fig. 10)
  lm     LM substrate step times (reduced configs)
  roofline  §Roofline terms from dry-run artifacts (if present)

Multi-device sections run in subprocesses with forced host device counts.
``REPRO_BENCH_SCALE`` scales the Table-I suite (default 0.1);
``REPRO_BENCH_FAST=1`` (or ``--quick``) runs a reduced set for CI-style smoke
runs.

Besides the CSV on stdout, every run writes a machine-readable
``{name: {"us_per_call": float, "derived": str}}`` map of the same rows. The
file name comes from ``REPRO_BENCH_JSON`` when set, else
``BENCH_PR<REPRO_PR_NUMBER>.json``, else ``BENCH.json``. CI uploads it as the
``bench-trajectory`` artifact and ``benchmarks/compare.py`` gates the next
run against it (>25% per-row regressions fail).

  krylov  IC(0)-PCG iteration cost, suite x comm/partition x RHS batch
  auto    session-API auto picks vs fixed backends + context cache hit rate
  service solves/sec at a multi-tenant request mix (batched vs one-by-one)
"""
from __future__ import annotations

import contextlib
import io
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import run_with_devices  # noqa: E402


class _Tee(io.TextIOBase):
    """Mirror writes to the real stdout while recording them for the JSON dump."""

    def __init__(self, stream):
        self.stream = stream
        self.buffer_text = io.StringIO()

    def write(self, s: str) -> int:
        self.buffer_text.write(s)
        return self.stream.write(s)

    def flush(self) -> None:
        self.stream.flush()


def provenance() -> dict:
    """Environment fingerprint stored under the ``_provenance`` key of the
    bench JSON: enough to explain a cross-run timing shift (different jax,
    different device fleet, different commit) without gating on it. Every
    field degrades to a placeholder rather than failing the bench run."""
    prov = {"timestamp_utc": "", "jax_version": "", "platform": "",
            "device_kind": "", "device_count": 0, "git_sha": ""}
    import datetime

    prov["timestamp_utc"] = datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds")
    try:
        import jax

        prov["jax_version"] = jax.__version__
        devs = jax.devices()
        prov["platform"] = devs[0].platform if devs else ""
        prov["device_kind"] = devs[0].device_kind if devs else ""
        prov["device_count"] = len(devs)
    except Exception:
        pass
    try:
        import subprocess

        prov["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10,
        ).stdout.strip()
    except Exception:
        pass
    return prov


def rows_from_csv(text: str) -> dict:
    """Parse ``name,us_per_call,derived`` lines into the JSON row map."""
    rows = {}
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] in ("", "name"):
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows[parts[0]] = {"us_per_call": us,
                          "derived": parts[2] if len(parts) > 2 else ""}
    return rows


def main() -> None:
    tee = _Tee(sys.stdout)
    with contextlib.redirect_stdout(tee):
        print("name,us_per_call,derived")
        fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1" or "--quick" in sys.argv[1:]
        scale = os.environ.get("REPRO_BENCH_SCALE", "0.05" if fast else "0.1")
        env = {"REPRO_BENCH_SCALE": scale}

        # plan-level analysis (no devices)
        from benchmarks import bench_comm_volume, bench_interconnect_model

        bench_comm_volume.main()
        bench_interconnect_model.main()

        # multi-device sections (subprocess with forced device count)
        print(run_with_devices("benchmarks.bench_scenarios", 4, env), end="")
        auto_env = dict(env, REPRO_BENCH_FAST="1" if fast else "0")
        print(run_with_devices("benchmarks.bench_auto", 4, auto_env), end="")
        # serving axis: solves/sec at a request mix (single device; the
        # coalesce-win gate in compare.py keys on these rows in every mode)
        print(run_with_devices("benchmarks.bench_service", 1, env), end="")
        if not fast:
            print(run_with_devices("benchmarks.bench_krylov", 4, env), end="")
            print(run_with_devices("benchmarks.bench_tasks", 4, env), end="")
            print(run_with_devices("benchmarks.bench_scaling", 8, env), end="")
            print(run_with_devices("benchmarks.bench_lm_step", 1, env), end="")

        # roofline table from dry-run artifacts, if the sweep has run
        if os.path.isdir("experiments/dryrun"):
            from benchmarks import roofline

            rows = [r for r in map(roofline.roofline_row, roofline.load_cells()) if r]
            for r in rows:
                name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
                derived = (
                    f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.2f};"
                    f"useful={r['useful_flops_ratio']:.2f}"
                )
                print(f"{name},{r['bound_s']*1e6:.1f},{derived}")

    pr = os.environ.get("REPRO_PR_NUMBER")
    default = f"BENCH_PR{pr}.json" if pr else "BENCH.json"
    out = os.environ.get("REPRO_BENCH_JSON", default)
    blob = rows_from_csv(tee.buffer_text.getvalue())
    # "_"-prefixed keys are metadata, not timing rows: compare.py ignores
    # them for gating and surfaces provenance next to failures
    blob["_provenance"] = provenance()
    from repro.obs.metrics import get_registry

    snap = get_registry().snapshot()
    if snap:
        blob["_metrics"] = snap
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    sys.stderr.write(f"[bench] wrote {out}\n")


if __name__ == "__main__":
    main()
