"""SpTRSV as the hot path of a real preconditioned Krylov solve (paper §I).

An SPD system derived from a structured-grid factor is solved with IC(0)-PCG:
every iteration applies the preconditioner as TWO distributed triangular
solves (L forward, L^T backward through the transposed plan) plus one
distributed SpMV — all three compiled exactly once and reused for every
iteration and every right-hand side in the batch. The unpreconditioned CG
baseline shows what those triangular solves buy.

Run:  PYTHONPATH=src python examples/preconditioner.py
"""
import jax
import numpy as np

from repro import compat
from repro.core import SolverConfig
from repro.krylov import solve_cg, solve_ic0_pcg, spd_lower_from_triangular
from repro.sparse import suite

a = spd_lower_from_triangular(suite.grid2d_factor(40, seed=0))  # SPD, n=1600
rng = np.random.default_rng(0)
b = rng.uniform(-1, 1, a.n)

D = len(jax.devices())
mesh = compat.make_mesh((D,), ("x",))
cfg = SolverConfig(block_size=32, comm="zerocopy", partition="taskpool")

plain = solve_cg(a, b, mesh=mesh, config=cfg, tol=1e-8)
print(f"CG (no preconditioner): {plain.n_iters:3d} iters, "
      f"relres {float(np.max(plain.relres)):.2e}")

res = solve_ic0_pcg(a, b, mesh=mesh, config=cfg, tol=1e-8)
fwd, bwd = res.info["forward"], res.info["backward"]
print(f"IC(0)-PCG:              {res.n_iters:3d} iters, "
      f"relres {float(np.max(res.relres)):.2e}")
print(f"distributed SpTRSV invocations: {fwd.n_solves} forward (L) + "
      f"{bwd.n_solves} backward (L^T), one compiled plan each")

# multi-RHS: the same compiled solves serve a whole panel of systems
B = rng.uniform(-1, 1, (a.n, 8))
panel = solve_ic0_pcg(a, B, mesh=mesh, config=cfg, tol=1e-8)
print(f"8-RHS panel:            {panel.n_iters:3d} iters, "
      f"{panel.info['forward'].n_solves} forward solves total "
      f"(amortized over all 8 systems)")
