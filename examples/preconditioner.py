"""SpTRSV as the triangular-solve step of a preconditioned iterative method.

The paper motivates SpTRSV as the kernel inside preconditioners (§I). Here a
perturbed system ``A = L + E`` is solved by preconditioned Richardson
iteration with ``M = L``: each sweep applies one distributed zero-copy
triangular solve (the plan/compile is reused across all iterations — the
"solver invoked 100x" pattern the paper benchmarks).

Run:  PYTHONPATH=src python examples/preconditioner.py
"""
import jax
import numpy as np

from repro.core import DistributedSolver, SolverConfig, build_plan
from repro.sparse import suite
from repro.sparse.matrix import to_scipy

a = suite.grid2d_factor(40, seed=0)  # structured-grid factor, n=1600
L = to_scipy(a).tocsr()
rng = np.random.default_rng(0)
E = L.copy()
E.data = E.data * rng.uniform(-0.01, 0.01, E.nnz)  # 1% perturbation of L
A = (L + E).tocsr()

b = rng.uniform(-1, 1, a.n)
D = len(jax.devices())
mesh = jax.make_mesh((D,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
plan = build_plan(a, D, SolverConfig(block_size=32, comm="zerocopy",
                                     partition="taskpool"))
solver = DistributedSolver(plan, mesh)  # compile once, reuse per sweep

x = np.zeros(a.n)
for it in range(30):
    r = b - A @ x
    res = np.linalg.norm(r) / np.linalg.norm(b)
    if it % 5 == 0:
        print(f"iter {it:2d}  relative residual {res:.3e}")
    if res < 1e-10:
        break
    x = x + solver.solve(r)
print(f"converged: ||Ax-b||/||b|| = {np.linalg.norm(A@x-b)/np.linalg.norm(b):.3e}")
