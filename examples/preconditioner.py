"""SpTRSV as the hot path of a real preconditioned Krylov solve (paper §I).

An SPD system derived from a structured-grid factor is solved with IC(0)-PCG
through one :class:`repro.api.SpTRSVContext`: the sparsity pattern is
analysed exactly once, the IC(0) factor is *factorized* into that analysis
(numeric refresh — no re-partitioning), and every iteration applies the
preconditioner as TWO context solves (L forward, L^T backward through the
lazy transpose extension of the same handle) plus one distributed SpMV.
The unpreconditioned CG baseline shows what those triangular solves buy, and
a refactorization step shows values changing under a fixed pattern without
recompiling anything.

Run:  PYTHONPATH=src python examples/preconditioner.py
"""
import jax
import numpy as np

from repro import compat
from repro.api import PlanOptions, SpTRSVContext
from repro.krylov import solve_cg, solve_ic0_pcg, spd_lower_from_triangular
from repro.sparse import suite
from repro.sparse.matrix import CSR

a = spd_lower_from_triangular(suite.grid2d_factor(40, seed=0))  # SPD, n=1600
rng = np.random.default_rng(0)
b = rng.uniform(-1, 1, a.n)

D = len(jax.devices())
mesh = compat.make_mesh((D,), ("x",))
ctx = SpTRSVContext(mesh=mesh,
                    options=PlanOptions(block_size=32, comm="zerocopy",
                                        partition="taskpool"))

plain = solve_cg(a, b, context=ctx, tol=1e-8)
print(f"CG (no preconditioner): {plain.n_iters:3d} iters, "
      f"relres {float(np.max(plain.relres)):.2e}")

res = solve_ic0_pcg(a, b, context=ctx, tol=1e-8)
fwd, bwd = res.info["forward"], res.info["backward"]
print(f"IC(0)-PCG:              {res.n_iters:3d} iters, "
      f"relres {float(np.max(res.relres)):.2e}")
print(f"distributed SpTRSV invocations: {fwd.n_solves} forward (L) + "
      f"{bwd.n_solves} backward (L^T), one analysis for the whole pattern "
      f"({ctx.stats()['analyses']} total)")

# multi-RHS: the same compiled solves serve a whole panel of systems
B = rng.uniform(-1, 1, (a.n, 8))
panel = solve_ic0_pcg(a, B, context=ctx, tol=1e-8)
print(f"8-RHS panel:            {panel.n_iters:3d} iters, "
      f"{panel.info['forward'].n_solves} forward solves total "
      f"(amortized over all 8 systems)")

# refactorization: new numeric values on the same pattern refresh the factor
# and re-arm the compiled executors — zero re-analysis, zero recompilation.
# The refreshed preconditioner feeds pcg directly; the SpMV picks up the new
# values through the pattern cache (analyse on a value change auto-refreshes).
from repro.krylov import DistributedSpMV, pcg

a_new = CSR(n=a.n, row_ptr=a.row_ptr, col_idx=a.col_idx, val=a.val * 1.2)
pre = res.info["preconditioner"].refresh(a_new)
spmv = DistributedSpMV(ctx.plan(ctx.analyse(a_new)), mesh)
res2 = pcg(spmv.matvec, b, psolve=pre, tol=1e-8)
st = ctx.stats()
print(f"after refactorization:  {res2.n_iters:3d} iters, still "
      f"{st['analyses']} analyses; cache hit rate {st['cache_hit_rate']:.0%}")
