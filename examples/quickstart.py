"""Quickstart: distributed zero-copy SpTRSV in 30 lines.

Builds a Table-I-like sparse lower-triangular system, analyses it, and solves
it under the paper's four design scenarios, verifying against scipy.

Run:  PYTHONPATH=src python examples/quickstart.py
(multi-device: XLA_FLAGS=--xla_force_host_platform_device_count=4)
"""
import jax
import numpy as np

from repro import compat
from repro.core import SolverConfig, build_plan, cut_stats, metrics, sptrsv
from repro.core.analysis import level_sets
from repro.sparse import suite
from repro.sparse.matrix import reference_solve

a = suite.random_levelled(n=2000, levels=64, avg_deps=4.0, seed=0)
m = metrics(a, level_sets(a))
print(f"matrix: n={m.n} nnz={m.nnz} levels={m.n_levels} "
      f"dependency={m.dependency:.2f} parallelism={m.parallelism:.0f}")

b = np.random.default_rng(0).uniform(-1, 1, a.n)
x_ref = reference_solve(a, b)

D = len(jax.devices())
mesh = compat.make_mesh((D,), ("x",))
print(f"devices: {D}")

for name, cfg in {
    "unified (UM analogue)": SolverConfig(comm="unified", partition="contiguous"),
    "shmem (zerocopy, contiguous)": SolverConfig(comm="zerocopy", partition="contiguous"),
    "zerocopy + task pool": SolverConfig(comm="zerocopy", partition="taskpool"),
    "zerocopy + malleable cost model": SolverConfig(comm="zerocopy", partition="malleable"),
    "sync-free runtime frontier": SolverConfig(comm="zerocopy", sched="syncfree"),
}.items():
    x = sptrsv(a, b, mesh=mesh, config=cfg)
    err = np.abs(x - x_ref).max() / np.abs(x_ref).max()
    plan = build_plan(a, D, cfg)
    cs = cut_stats(plan.bs, plan.part)
    print(f"{name:32s} rel.err={err:.2e}  comm/solve={plan.comm_bytes_per_solve/1e3:.0f}KB"
          f"  level-imbalance={cs.level_imbalance:.2f}")
