"""Quickstart: the analyse/factorize/solve session API in 40 lines.

Builds a Table-I-like sparse lower-triangular system, analyses it ONCE per
option set, solves it under the paper's design scenarios, refreshes the
numeric values without re-analysis, and lets auto mode pick the backend.

Run:  PYTHONPATH=src python examples/quickstart.py
(multi-device: XLA_FLAGS=--xla_force_host_platform_device_count=4)
"""
import jax
import numpy as np

from repro import compat
from repro.api import PlanOptions, SpTRSVContext
from repro.core import cut_stats, metrics
from repro.core.analysis import level_sets
from repro.sparse import suite
from repro.sparse.matrix import CSR, reference_solve

a = suite.random_levelled(n=2000, levels=64, avg_deps=4.0, seed=0)
m = metrics(a, level_sets(a))
print(f"matrix: n={m.n} nnz={m.nnz} levels={m.n_levels} "
      f"dependency={m.dependency:.2f} parallelism={m.parallelism:.0f}")

b = np.random.default_rng(0).uniform(-1, 1, a.n)
x_ref = reference_solve(a, b)

D = len(jax.devices())
mesh = compat.make_mesh((D,), ("x",))
print(f"devices: {D}")

ctx = SpTRSVContext(mesh=mesh)  # one session: analyses and executors cached

for name, opts in {
    "unified (UM analogue)": PlanOptions(comm="unified", partition="contiguous"),
    "shmem (zerocopy, contiguous)": PlanOptions(comm="zerocopy", partition="contiguous"),
    "zerocopy + task pool": PlanOptions(comm="zerocopy", partition="taskpool"),
    "zerocopy + malleable cost model": PlanOptions(comm="zerocopy", partition="malleable"),
    "sync-free runtime frontier": PlanOptions(comm="zerocopy", sched="syncfree"),
}.items():
    h = ctx.analyse(a, opts)
    x = ctx.solve(h, b)
    err = np.abs(x - x_ref).max() / np.abs(x_ref).max()
    plan = ctx.plan(h)
    cs = cut_stats(plan.bs, plan.part)
    print(f"{name:32s} rel.err={err:.2e}  comm/solve={plan.comm_bytes_per_solve/1e3:.0f}KB"
          f"  level-imbalance={cs.level_imbalance:.2f}")

# factorize: new numeric values on the SAME pattern — no re-analysis, the
# compiled executors are re-armed in place (the ILU-refactorization workflow)
a2 = CSR(n=a.n, row_ptr=a.row_ptr, col_idx=a.col_idx, val=a.val * 1.5)
h = ctx.analyse(a, PlanOptions(comm="zerocopy", partition="taskpool"))
ctx.factorize(a2, h)
x2 = ctx.solve(h, b)
err2 = np.abs(x2 - reference_solve(a2, b)).max() / np.abs(x2).max()
print(f"{'numeric refresh (same pattern)':32s} rel.err={err2:.2e}")

# auto mode: score sched x comm x kernel with the calibrated cost model
h = ctx.analyse(a, PlanOptions.auto(probe_solves=0))
sched, comm, kernel = h.auto.chosen
x3 = ctx.solve(h, b)
err3 = np.abs(x3 - x_ref).max() / np.abs(x_ref).max()
print(f"{'auto (' + sched + '/' + comm + '/' + kernel + ')':32s} rel.err={err3:.2e}")

st = ctx.stats()
print(f"session: {st['analyses']} analyses for {st['solves']} solves, "
      f"cache hit rate {st['cache_hit_rate']:.0%}")
