"""Batched serving: prefill a request batch, then greedy-decode new tokens.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b --new-tokens 32
Uses the reduced config on CPU; the same engine lowers at full config in the
dry-run (decode_32k / long_500k cells).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models import init_cache, init_params
from repro.serve.engine import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    mesh = make_host_mesh()
    with compat.set_mesh(mesh):
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        B, P, T = args.batch, args.prompt_len, args.new_tokens
        max_seq = P + T
        cache = init_cache(cfg, B, max_seq)
        prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
        batch = {"tokens": prompts}

        prefill = make_prefill_step(cfg, mesh, example_params=params,
                                    example_cache=cache, example_batch=batch)
        logits, cache = prefill(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)

        dec_batch = {"tokens": next_tok[:, None]}
        decode = make_decode_step(cfg, mesh, example_params=params,
                                  example_cache=cache, example_batch=dec_batch)
        out = [next_tok]
        t0 = time.perf_counter()
        for t in range(T - 1):
            next_tok, cache = decode(params, {"tokens": next_tok[:, None]},
                                     cache, jnp.int32(P + t))
            out.append(next_tok)
        dt = time.perf_counter() - t0
        toks = jnp.stack(out, axis=1)
        print(f"{args.arch}: decoded {toks.shape} in {dt:.2f}s "
              f"({B*(T-1)/max(dt,1e-9):.1f} tok/s)")
        print("first sequence:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
