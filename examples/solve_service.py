"""SpTRSV-as-a-service: a multi-tenant worker over a persistent plan store.

Production triangular solves arrive as *requests*: many tenants, a few hot
sparsity patterns (the preconditioner factors every iterative solver hammers)
plus a cold tail, each request a fresh right-hand side. This example stands
up the ISSUE-9 serving stack twice over the same plan-store directory:

* the COLD worker pays one symbolic analysis per pattern, persists each plan,
  and coalesces same-pattern requests into multi-RHS panels;
* the WARM worker — a brand-new process in real life — serves the same mix
  with ZERO symbolic analyses: every plan deserializes from the store,
  passes the strict static verifier, and rehydrates its numeric values from
  the tenant's matrix.

Run:  PYTHONPATH=src python examples/solve_service.py
"""
import shutil
import tempfile

import numpy as np

from repro.api import PlanOptions
from repro.service import SolveEngine
from repro.sparse import suite
from repro.sparse.matrix import reference_solve

store_dir = tempfile.mkdtemp(prefix="sptrsv-plans-")
rng = np.random.default_rng(0)

# three tenant-facing patterns: one hot, two cold
hot = suite.random_levelled(600, 24, 4.0, seed=0)
cold = [suite.random_levelled(300, 12, 4.0, seed=1),
        suite.grid2d_factor(14, seed=2)]
patterns = [hot] + cold
mix = [0, 0, 1, 0, 0, 2, 0, 0, 1, 0, 0, 0]  # ~70% of traffic on the hot one


def serve(label):
    engine = SolveEngine(options=PlanOptions(block_size=32),
                         plan_store=store_dir, max_batch=8)
    tickets = [engine.submit(f"tenant{i % 4}", patterns[p],
                             rng.uniform(-1, 1, patterns[p].n).astype(np.float32))
               for i, p in enumerate(mix)]
    engine.drain()
    for t in tickets:  # every served answer checks out against scipy
        ref = reference_solve(t.request.matrix, t.request.rhs)
        assert np.allclose(t.result(0), ref, atol=1e-4 * np.abs(ref).max())
    s = engine.stats()
    width = s["coalesced_columns"] / s["batches"]
    print(f"{label}: {s['results']} requests in {s['batches']} batches "
          f"(coalesce width {width:.1f}), "
          f"analyses={s['session'].get('analyses', 0)}, "
          f"plan-store hits={s['session'].get('plan_store_hits', 0)}, "
          f"store hit rate {s['plan_store']['hit_rate']:.0%}")
    return s


cold_stats = serve("cold worker")
warm_stats = serve("warm worker")  # fresh engine, same store directory
assert warm_stats["session"].get("analyses", 0) == 0, \
    "warm worker should not run any symbolic analysis"
print(f"plan store {store_dir}: the warm worker deserialized every plan "
      "(strict-verified) instead of re-analysing")
shutil.rmtree(store_dir)
