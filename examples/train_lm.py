"""End-to-end training driver: ~100M-param llama-style model, synthetic data.

Exercises the full substrate on one host: model init -> sharded train step
(remat, AdamW, cosine LR) -> checkpoint/resume -> loss curve. The same loop
scales to the production mesh via --production-mesh on a pod.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
(a CPU step at this size takes seconds; use --steps 10 for a smoke run)
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro import compat
from repro.configs import get_reduced
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, param_count
from repro.train.optim import adamw_init
from repro.train.step import make_train_step


def config_100m():
    return dataclasses.replace(
        get_reduced("llama3.2-1b"),
        n_layers=10, d_model=768, n_heads=12, n_kv=6, head_dim=64,
        d_ff=3072, vocab=32000,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m()
    mesh = make_host_mesh()
    with compat.set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        print(f"model: {param_count(params)/1e6:.1f}M params")
        opt = adamw_init(params)
        data = SyntheticLM(cfg, args.global_batch, args.seq_len)
        step = make_train_step(
            cfg, mesh, peak_lr=3e-4, warmup=20, total_steps=args.steps,
            example_params=params, example_opt=opt, example_batch=data.batch(0),
        )
        from repro.checkpoint import CheckpointManager

        mgr = CheckpointManager(args.ckpt_dir)
        start = 0
        if (last := mgr.latest_step()) is not None:
            params, opt, man = mgr.restore(last, params, opt)
            start = man["step"] + 1
            print(f"resumed from step {last}")
        import time

        for s in range(start, args.steps):
            t0 = time.perf_counter()
            params, opt, metr = step(params, opt, data.batch(s), np.int32(s))
            if s % 10 == 0 or s == args.steps - 1:
                print(f"step {s:4d}  loss {float(metr['loss']):.4f}  "
                      f"lr {float(metr['lr']):.2e}  {time.perf_counter()-t0:.2f}s")
            if (s + 1) % 50 == 0:
                mgr.save(s, params, opt, {"arch": "train_lm_100m"})


if __name__ == "__main__":
    main()
