"""repro: multi-pod JAX framework reproducing zero-copy SpTRSV (Xie et al., 2020)."""

__version__ = "1.0.0"
