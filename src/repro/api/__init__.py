"""Session front door: analyse / factorize / solve with plan caching and
auto-tuned backend selection (the classic sparse-solver lifecycle)."""
from repro.api.autotune import AutoDecision, estimate_plan_cost
from repro.api.context import SpTRSVContext, SpTRSVHandle, pattern_key
from repro.api.options import (
    AUTO,
    Comm,
    KernelBackend,
    PartitionStrategy,
    PlanOptions,
    Sched,
    as_options,
)
