"""Backend auto-tuning for the session API.

The right execution mode is matrix-dependent: a chain-skewed factor wants the
fused megakernel's low launch count (and ``dagpart``'s merged supersteps,
which collapse a long run of narrow levels into a handful of grid steps), a
wide shallow DAG wants the syncfree frontier, a heavily cut partition may
prefer unified's dense psum over many packed exchanges. ``PlanOptions`` marks any of ``sched``/``comm``/``kernel``
as ``auto`` and this module resolves them:

1. enumerate the candidate (sched, comm, kernel) combinations — all sharing
   ONE partition, so auto-tuning never re-analyses the pattern;
2. score each candidate plan with the calibrated block-op cost model
   (:mod:`repro.core.costmodel` weights x the plan's bucketized schedule
   widths, plus comm-byte and dispatch-overhead terms);
3. optionally (``probe_solves > 0``) compile each candidate and measure real
   probe solves at the expected RHS width, choosing the measured minimum.

The decision — chosen combination, per-candidate scores/timings, probe
overhead — is recorded as an :class:`AutoDecision` and surfaced through
``SpTRSVContext.dispatch_stats``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.core.costmodel import FLOPS_PER_BYTE, calibrate_weights
from repro.core.solver import (
    DistributedSolver,
    Plan,
    dispatch_stats,
    fused_streaming,
    level_widths,
    stream_dma_bytes_per_solve,
)
from repro.kernels import ops
from repro.obs import calibration as _calibration
from repro.obs.trace import get_tracer

# One executor dispatch (gather+kernel launch or collective) costs about this
# many block-op units in the model — the knob that lets launch-bound schedules
# (many tiny levels) prefer the fused path.
DISPATCH_OVERHEAD = 8.0

# Off-TPU the superstep megakernel runs in Pallas interpret mode (pure-Python
# per grid step) — never the fast choice; the model must know what probes
# would measure.
INTERPRET_PENALTY = 100.0

SCHED_CANDIDATES = ("levelset", "dagpart", "syncfree")
COMM_CANDIDATES = ("zerocopy", "unified")


def kernel_candidates() -> tuple:
    """Platform default executor plus the fused megakernel paths (resident
    store and streaming HBM tile store)."""
    return (ops.executor_backend(None), "fused", "fused_streamed")


@dataclasses.dataclass(frozen=True)
class AutoDecision:
    """Record of one auto-tuning pass (kept on the analysis handle)."""

    chosen: tuple  # (sched, comm, kernel)
    mode: str  # "probed" | "modelled"
    scores: dict  # (sched, comm, kernel) -> model score, block-op units
    probe_us: dict  # (sched, comm, kernel) -> measured us/solve ({} unless probed)
    probe_overhead_us: float  # wall time spent probing (compile + measure)
    # (sched, comm, kernel) -> wall time of the candidate's first (compiling)
    # solve, kept OUT of probe_us so the measured ranking never depends on
    # which candidate compiled last ({} unless probed)
    compile_us: dict = dataclasses.field(default_factory=dict)

    def as_derived(self) -> str:
        """Compact ``k=v;...`` form for bench rows / dispatch_stats."""
        sched, comm, kernel = self.chosen
        return (f"sched={sched};comm={comm};kernel={kernel};mode={self.mode};"
                f"probe_overhead_us={self.probe_overhead_us:.0f}")


def plan_work_units(plan: Plan, R: int = 1) -> tuple[float, float, float]:
    """``(su, tu, tf)`` schedule work units for one solve at RHS width R:
    the regressors of the compute term ``w_solve*su + w_tile_mem*tu +
    w_tile_flop*tf``. Shared by :func:`estimate_plan_cost` and the
    calibration feedback recorder so fitted weights mean exactly what the
    scorer multiplies them by."""
    cfg = plan.config
    wid = level_widths(plan) if plan.n_levels else np.zeros((0, 3), np.int64)
    fused = ops.executor_backend(cfg.kernel_backend) in ops.FUSED_BACKENDS
    if cfg.sched != "syncfree" or fused:
        # frontier-bucketed syncfree work is approximated by the same
        # per-level schedule widths the levelset executors dispatch
        n_solve, n_tiles = float(wid[:, 0].sum()), float(wid[:, 1].sum())
    else:
        # dense masked scan: every sweep touches all local rows and tiles
        sweeps = plan.n_supersteps
        n_solve = float(sweeps * plan.local_rows.shape[1])
        n_tiles = float(sweeps * plan.tiles.shape[1])
    return n_solve * R, n_tiles, n_tiles * R


def estimate_plan_cost(plan: Plan, R: int = 1) -> float:
    """Model one solve of ``plan`` in calibrated block-op units.

    Compute term: the bucketized per-level schedule widths (the work the
    executors actually dispatch, not raw row counts) weighted by the
    per-backend TRSV/GEMV weights from :func:`calibrate_weights`. Comm term:
    ``comm_bytes_per_solve`` at the cost model's machine balance, in units of
    one B^2-flop block op. Overhead term: dispatch/launch counts from
    :func:`dispatch_stats` (levelset) or one sweep per superstep (syncfree).
    """
    cfg = plan.config
    B = plan.bs.B
    w_solve, w_tile_mem, w_tile_flop = calibrate_weights(B, backend=cfg.kernel_backend)
    backend = ops.executor_backend(cfg.kernel_backend)
    fused = backend in ops.FUSED_BACKENDS
    su, tu, tf = plan_work_units(plan, R)
    compute = w_solve * su + w_tile_mem * tu + w_tile_flop * tf
    if cfg.sched != "syncfree":
        ds = dispatch_stats(plan)
        launches = (ds["fused_launches"] if fused
                    else ds["switch_dispatches"]) + ds["exchanges"]
    else:
        launches = 2 * plan.n_supersteps  # one solve + one update dispatch per sweep
    comm = plan.comm_bytes_per_solve * FLOPS_PER_BYTE / (B * B)
    # streaming buys bounded VMEM residency with per-level HBM DMA bursts;
    # score those bytes at the machine balance like the collective payload
    # (fused_streaming also covers plain "fused" auto-upgraded past the
    # VMEM limit, so the model prices what would actually execute)
    dma = 0.0
    if fused and fused_streaming(plan, R):
        dma = stream_dma_bytes_per_solve(plan) * FLOPS_PER_BYTE / (B * B)
    cost = compute + comm + dma + DISPATCH_OVERHEAD * launches
    if fused and cfg.sched != "syncfree" and ops.interpret_mode():
        cost *= INTERPRET_PENALTY
    return cost


def candidate_grid(options, n_devices: int | None = None) -> list:
    """All concrete (sched, comm, kernel) combos for ``options``' auto dims.

    On one device comm is vacuous (no collectives execute), so an auto comm
    axis collapses to zerocopy instead of probing the same program twice.
    """
    from repro.api.options import Comm, KernelBackend, Sched

    scheds = SCHED_CANDIDATES if options.sched == Sched.AUTO else (options.sched.value,)
    comms = COMM_CANDIDATES if options.comm == Comm.AUTO else (options.comm.value,)
    if n_devices == 1 and options.comm == Comm.AUTO:
        comms = ("zerocopy",)
    kernels = (kernel_candidates() if options.kernel == KernelBackend.AUTO
               else (options.kernel.value,))
    return list(itertools.product(scheds, comms, kernels))


def tune(a, options, mesh, *, part=None, bs=None):
    """Resolve ``options``' auto dimensions for matrix ``a`` on ``mesh``.

    Returns ``(config, plan, decision, solver)`` — the winning concrete
    :class:`SolverConfig`, its plan (built on the shared partition), the
    :class:`AutoDecision`, and, when probing compiled the winner anyway, its
    ready-to-use :class:`DistributedSolver` (else ``None``).
    """
    from repro.core.blocking import build_blocks, pad_rhs
    from repro.core.partition import make_partition

    D = int(mesh.devices.size)
    if bs is None:
        bs = build_blocks(a, options.block_size)
    if part is None:
        part = make_partition(bs, D, options.partition.value,
                              options.tasks_per_device, cost_R=options.rhs_hint)
    combos = candidate_grid(options, D)
    from repro.core.solver import build_plan

    plans, scores = {}, {}
    with get_tracer().span("sptrsv.autotune", n_candidates=len(combos),
                           probe_solves=options.probe_solves) as tspan:
        for combo in combos:
            sched, comm, kernel = combo
            if kernel == "fused_streamed" and (sched, comm, "fused") in plans:
                # drop combos that resolve to a byte-identical executor as an
                # already-enumerated candidate — same principle as the comm
                # collapse above, never compile/probe the same program twice:
                # syncfree defines fused_streamed == fused, and a levelset plan
                # past the VMEM limit auto-streams plain "fused" anyway
                if sched == "syncfree" or fused_streaming(
                        plans[(sched, comm, "fused")], options.rhs_hint):
                    continue
            cfg = options.to_config(sched=sched, comm=comm, kernel=kernel)
            plans[combo] = build_plan(a, D, cfg, part=part)
            scores[combo] = estimate_plan_cost(plans[combo], R=options.rhs_hint)
        combos = [c for c in combos if c in plans]

        probe_us: dict = {}
        compile_us: dict = {}
        solvers: dict = {}
        t_probe0 = time.perf_counter()
        if options.probe_solves > 0 and len(combos) > 1:
            import jax
            import jax.numpy as jnp

            rng = np.random.default_rng(0)
            R = options.rhs_hint
            b = rng.uniform(-1, 1, (a.n, R) if R > 1 else a.n).astype(np.float32)
            b_blocks = jnp.asarray(pad_rhs(b, bs))
            store = _calibration.get_store()
            for combo in combos:
                with get_tracer().span("sptrsv.probe", sched=combo[0],
                                       comm=combo[1], kernel=combo[2]) as sp:
                    solver = DistributedSolver(plans[combo], mesh)
                    solvers[combo] = solver
                    # the first solve pays compilation: record it separately and
                    # follow with an untimed warmup so the measured ranking never
                    # depends on which candidate happened to compile last
                    t_c = time.perf_counter()
                    jax.block_until_ready(solver.solve_blocks(b_blocks))
                    compile_us[combo] = (time.perf_counter() - t_c) * 1e6
                    jax.block_until_ready(solver.solve_blocks(b_blocks))
                    times = []
                    for _ in range(options.probe_solves):
                        t0 = time.perf_counter()
                        jax.block_until_ready(solver.solve_blocks(b_blocks))
                        times.append(time.perf_counter() - t0)
                    times.sort()
                    probe_us[combo] = times[len(times) // 2] * 1e6
                    sp.set(probe_us=probe_us[combo], compile_us=compile_us[combo])
                # feedback loop: the measured solve is a wall-clock sample of the
                # cost model's compute term — persist it for probe-free sessions
                su, tu, tf = plan_work_units(plans[combo], R)
                store.record(
                    backend=ops.executor_backend(combo[2]), B=plans[combo].bs.B,
                    signature=_calibration.probe_signature(plans[combo], R),
                    solve_units=su, tile_units=tu, tile_flop_units=tf, R=R,
                    measured_us=probe_us[combo],
                )
            chosen = min(combos, key=lambda c: probe_us[c])
            mode = "probed"
        else:
            chosen = min(combos, key=lambda c: scores[c])
            mode = "modelled"
        overhead = (time.perf_counter() - t_probe0) * 1e6 if probe_us else 0.0
        decision = AutoDecision(chosen=chosen, mode=mode, scores=scores,
                                probe_us=probe_us, probe_overhead_us=overhead,
                                compile_us=compile_us)
        tspan.set(chosen="/".join(chosen), mode=mode,
                  probe_overhead_us=overhead)
    cfg = options.to_config(sched=chosen[0], comm=chosen[1], kernel=chosen[2])
    return cfg, plans[chosen], decision, solvers.get(chosen)
