"""The analyse/factorize/solve session front door.

The paper's pipeline is explicitly staged: symbolic dependency analysis and
partitioning happen ONCE per sparsity pattern, then many numeric solves
amortize it. :class:`SpTRSVContext` is that lifecycle as an object:

* **analyse** — block structure + levels + partition + compacted schedules,
  keyed by a sparsity-*pattern* hash x options. The symbolic analysis is
  shared across every handle on the same pattern (a matrix and its zero-fill
  factor, or ILU's L and reversed-U on a symmetric pattern, partition
  exactly once); distinct numeric contents get distinct *handles* via
  ``tag`` so one factorization can never clobber another's values.
* **factorize** — numeric tile/diagonal refresh into the existing plan
  (:func:`repro.core.solver.refresh_plan`): ILU-style refactorization changes
  values, never structure, so compiled executors are retained and re-armed
  with the new arrays — zero re-partitioning, zero retracing.
* **solve** — cached compiled executors keyed by pattern x options x RHS
  width x transpose. The L and L^T/U sweeps of a preconditioner share one
  analysis: the transpose executor is a lazy extension of the same handle.

Auto mode (:class:`repro.api.options.PlanOptions` with ``sched``/``comm``/
``kernel`` set to ``"auto"``) resolves the execution mode per matrix at
analyse time via :mod:`repro.api.autotune`; the decision is recorded on the
handle and reported by :meth:`SpTRSVContext.dispatch_stats`.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time

import jax
import numpy as np

from repro import compat
from repro.api import autotune
from repro.api.options import KernelBackend, PlanOptions, as_options
from repro.obs.metrics import MetricsRegistry, get_registry, record_plan_metrics
from repro.obs.trace import get_tracer
from repro.core.blocking import BlockStructure, build_blocks
from repro.core.partition import Partition, make_partition
from repro.core.solver import (
    AXIS,
    DistributedSolver,
    Plan,
    SolverConfig,
    build_plan,
    dispatch_stats,
    refresh_plan,
)
from repro.sparse.matrix import CSR


def pattern_key(a: CSR) -> str:
    """Hash of the exact scalar sparsity pattern (structure only, no values)."""
    h = hashlib.sha1()
    h.update(np.int64(a.n).tobytes())
    h.update(np.ascontiguousarray(a.row_ptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(a.col_idx, dtype=np.int32).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class _Symbolic:
    """The per-pattern analysis every handle on that pattern shares."""

    bs: BlockStructure
    part: Partition
    # auto-tuning is a property of (pattern, options), not of the numeric
    # content: one tuner pass serves every tagged handle on this analysis
    tuned: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SpTRSVHandle:
    """One numeric factorization on one analysed pattern (opaque to callers).

    References the shared symbolic analysis (block structure, partition) and
    owns the current numeric plans (forward; transpose built lazily so
    L^T/U solves share the analysis), the compiled executors, and the
    auto-tuning decision.
    """

    pattern: str
    tag: str
    options: PlanOptions
    config: SolverConfig  # resolved (post-auto) engine config
    matrix: CSR  # current numeric values on this pattern
    symbolic: _Symbolic
    plan: Plan | None = None  # forward plan (lazy unless auto probing built it)
    tplan: Plan | None = None  # transpose plan (lazy)
    auto: autotune.AutoDecision | None = None
    solvers: dict = dataclasses.field(default_factory=dict)  # transpose -> solver
    shapes: set = dataclasses.field(default_factory=set)  # (transpose, R) compiled
    n_factorize: int = 0
    plan_store_hit: bool = False  # analysis came from the persistent store

    @property
    def part(self) -> Partition:
        return self.symbolic.part

    @property
    def bs(self) -> BlockStructure:
        return self.symbolic.bs


class SpTRSVContext:
    """Analyse-once / factorize-cheaply / solve-many session over one mesh.

    ``options`` set the session default; ``analyse``/``factorize`` accept
    per-call overrides. Counters (:meth:`stats`) audit the amortization:
    ``analyses`` counts real partition/schedule constructions (shared-pattern
    handles do NOT re-count), ``solves`` the executor invocations, and the
    cache hit rate covers re-analyse calls and executor/shape reuse.

    ``plan_store`` (a :class:`repro.service.planstore.PlanStore`, duck-typed)
    makes ``analyse`` consult the persistent store before running a symbolic
    analysis — a warm worker serves without a single partition/schedule
    construction (``plan_store_hits``, not ``analyses``) — and persists every
    freshly built plan. ``cache_capacity`` bounds the handle/executor cache
    LRU-style: the least-recently-used entry (its compiled executors with it)
    is dropped past the capacity, counted under ``session.evictions``.
    """

    def __init__(self, mesh: jax.sharding.Mesh | None = None,
                 options: PlanOptions | SolverConfig | None = None,
                 registry: MetricsRegistry | None = None,
                 plan_store=None, cache_capacity: int | None = None):
        self.mesh = mesh if mesh is not None else compat.make_mesh((1,), (AXIS,))
        self.options = as_options(options)
        self.registry = registry if registry is not None else get_registry()
        self.plan_store = plan_store
        if cache_capacity is not None and cache_capacity < 1:
            raise ValueError("cache_capacity must be >= 1 (or None: unbounded)")
        self.cache_capacity = cache_capacity
        self._entries: collections.OrderedDict[tuple, SpTRSVHandle] = \
            collections.OrderedDict()
        self._symbolic: dict[tuple, _Symbolic] = {}
        self._counters: collections.Counter = collections.Counter()

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    # -- cache bookkeeping ------------------------------------------------

    def _evict(self) -> None:
        # LRU bound on compiled state: handles (and their executors) drop
        # oldest-first; the cheap symbolic cache is deliberately retained so
        # a re-analysed pattern only recompiles, never re-partitions
        while (self.cache_capacity is not None
               and len(self._entries) > self.cache_capacity):
            self._entries.popitem(last=False)
            self._counters["evictions"] += 1
            self.registry.counter("session.evictions").inc()

    def _store_save(self, handle: SpTRSVHandle, plan: Plan) -> None:
        """Persist a freshly built plan; a read-only or full store degrades
        to no persistence, never to a failed solve."""
        if self.plan_store is None:
            return
        try:
            self.plan_store.save(plan, pattern=handle.pattern,
                                 options=handle.options)
        except Exception:
            self._counters["plan_store_save_errors"] += 1
            self.registry.counter("session.plan_store_save_errors").inc()

    # -- analyse ----------------------------------------------------------

    def _symbolic_key(self, pattern: str, opts: PlanOptions) -> tuple:
        # everything the partition construction reads; the kernel backend
        # only matters when it feeds calibrated malleable cost weights
        kernel = (opts.kernel.value
                  if opts.calibrate_cost else None)
        return (pattern, opts.block_size, opts.partition.value,
                opts.tasks_per_device, opts.rhs_hint, opts.calibrate_cost, kernel)

    def _analyse_symbolic(self, a: CSR, pattern: str, opts: PlanOptions) -> _Symbolic:
        key = self._symbolic_key(pattern, opts)
        sym = self._symbolic.get(key)
        if sym is not None:
            # a new handle (new tag / exec options) reusing the expensive
            # symbolic analysis is a cache hit the amortization stats must see
            self._counters["symbolic_hits"] += 1
            self.registry.counter("session.symbolic_hits").inc()
            return sym
        self._counters["analyses"] += 1
        self.registry.counter("session.analyses").inc()
        bs = build_blocks(a, opts.block_size)
        cost_weights = None
        if opts.calibrate_cost and opts.partition.value == "malleable":
            from repro.core.costmodel import calibrate_weights

            backend = (None if opts.kernel in (KernelBackend.AUTO, KernelBackend.DEFAULT)
                       else opts.kernel.value)
            cost_weights = calibrate_weights(opts.block_size, backend=backend)
        part = make_partition(bs, self.n_devices, opts.partition.value,
                              opts.tasks_per_device, cost_weights=cost_weights,
                              cost_R=opts.rhs_hint)
        sym = _Symbolic(bs=bs, part=part)
        self._symbolic[key] = sym
        return sym

    def analyse(self, a: CSR, options: PlanOptions | SolverConfig | None = None,
                *, tag: str = "") -> SpTRSVHandle:
        """Symbolic analysis of ``a``'s sparsity pattern (cached).

        The block structure and partition are computed once per pattern and
        shared; under auto options the backend tuner runs here (candidates
        share the one partition). ``tag`` names the numeric content: handles
        with different tags on the same pattern share the analysis but hold
        independent values (e.g. a matrix and its incomplete factor). The
        returned handle carries ``a``'s values until the next
        :meth:`factorize`.
        """
        opts = as_options(options) if options is not None else self.options
        pat = pattern_key(a)
        key = (pat, opts, tag)
        hit = self._entries.get(key)
        if hit is not None:
            self._counters["analysis_hits"] += 1
            self.registry.counter("session.analysis_hits").inc()
            self._entries.move_to_end(key)
            if hit.matrix is not a and not np.array_equal(hit.matrix.val, a.val):
                # same pattern, new numeric values: the analysis is a cache
                # hit but the values must not go stale — refresh in place
                self.factorize(a, hit)
            return hit
        with get_tracer().span("sptrsv.analyse", pattern=pat, tag=tag,
                               n=int(a.n), n_devices=self.n_devices) as span:
            plan = None
            if (self.plan_store is not None
                    and self._symbolic_key(pat, opts) not in self._symbolic):
                plan = self.plan_store.load(a, self.n_devices, opts)
            stored = plan is not None
            if stored:
                # persistent-store hit: the whole symbolic analysis — and the
                # resolved config, auto dimensions included — arrives
                # pre-built, value-hydrated against ``a``, and verified;
                # no partition/schedule construction runs at all
                sym = _Symbolic(bs=plan.bs, part=plan.part)
                config, decision, solver = plan.config, None, None
                if opts.is_auto:
                    sym.tuned[opts] = (config, None)
                self._symbolic[self._symbolic_key(pat, opts)] = sym
                self._counters["plan_store_hits"] += 1
                self.registry.counter("session.plan_store_hits").inc()
                span.set(plan_store_hit=True, sched=config.sched)
            elif opts.is_auto:
                sym = self._analyse_symbolic(a, pat, opts)
                tuned = sym.tuned.get(opts)
                if tuned is not None:
                    # another handle on this analysis already paid the tuner
                    # cost (candidate plans + probes) — reuse its decision
                    config, decision = tuned
                    plan, solver = None, None
                    self._counters["auto_reuses"] += 1
                else:
                    config, plan, decision, solver = autotune.tune(
                        a, opts, self.mesh, bs=sym.bs, part=sym.part)
                    sym.tuned[opts] = (config, decision)
                span.set(sched=config.sched, comm=config.comm,
                         kernel=config.kernel_backend or "default")
            else:
                sym = self._analyse_symbolic(a, pat, opts)
                config = opts.to_config()
                plan, decision, solver = None, None, None
        handle = SpTRSVHandle(pattern=pat, tag=tag, options=opts, config=config,
                              matrix=a, symbolic=sym, plan=plan, auto=decision,
                              plan_store_hit=stored)
        if solver is not None:  # probing already compiled the winner
            handle.solvers[False] = solver
            handle.shapes.add((False, opts.rhs_hint))
        if not stored and plan is not None:
            self._store_save(handle, plan)  # tuner already built the winner
        self._entries[key] = handle
        self._evict()
        return handle

    # -- factorize --------------------------------------------------------

    def factorize(self, a: CSR, handle: SpTRSVHandle | None = None,
                  options: PlanOptions | SolverConfig | None = None,
                  *, tag: str = "") -> SpTRSVHandle:
        """Numeric refresh: install ``a``'s values into an existing analysis.

        ``a`` must share the handle's exact sparsity pattern (checked by
        hash). Existing plans are value-refreshed and live executors re-armed
        without recompiling; with no handle given, the (pattern, options,
        tag) entry is looked up and analysed first if unseen.
        """
        if handle is None:
            opts = as_options(options) if options is not None else self.options
            handle = self._entries.get((pattern_key(a), opts, tag))
            if handle is None:
                handle = self.analyse(a, opts, tag=tag)
                self._counters["factorizes"] += 1
                handle.n_factorize += 1
                return handle
        else:
            # an explicit handle IS the target entry: options/tag that don't
            # match it would be silently ignored — reject the conflict
            if options is not None and as_options(options) != handle.options:
                raise ValueError(
                    "factorize: options conflict with the given handle's — "
                    "pass either a handle or options, not both"
                )
            if tag and tag != handle.tag:
                raise ValueError(
                    f"factorize: tag {tag!r} conflicts with the given "
                    f"handle's tag {handle.tag!r}"
                )
            if pattern_key(a) != handle.pattern:
                raise ValueError(
                    "factorize: sparsity pattern differs from the analysed "
                    "one — numeric refresh requires an identical pattern; "
                    "call analyse() for a new pattern"
                )
        self._counters["factorizes"] += 1
        self.registry.counter("session.factorizes").inc()
        handle.n_factorize += 1
        handle.matrix = a
        with get_tracer().span("sptrsv.factorize", pattern=handle.pattern,
                               tag=handle.tag, n_factorize=handle.n_factorize):
            if handle.plan is not None:
                handle.plan = refresh_plan(handle.plan, a)
                if False in handle.solvers:
                    handle.solvers[False].refresh(handle.plan)
            if handle.tplan is not None:
                handle.tplan = refresh_plan(handle.tplan, a)
                if True in handle.solvers:
                    handle.solvers[True].refresh(handle.tplan)
        return handle

    # -- solve ------------------------------------------------------------

    def solve(self, handle: SpTRSVHandle | CSR, b: np.ndarray, *,
              transpose: bool = False) -> np.ndarray:
        """Solve ``L x = b`` (or ``L^T x = b``) with the cached executor.

        ``b`` is ``(n,)`` or an ``(n, R)`` panel. Executors are cached per
        (pattern, options, tag, transpose); each (..., RHS width) combination
        compiles once and is a cache hit afterwards.
        """
        if isinstance(handle, CSR):
            handle = self.analyse(handle)
        key = (handle.pattern, handle.options, handle.tag)
        if key in self._entries:  # LRU: a served handle is recently used
            self._entries.move_to_end(key)
        solver = self.executor(handle, transpose=transpose)
        b = np.asarray(b)
        R = b.shape[1] if b.ndim == 2 else 1
        shape = (transpose, R)
        if shape in handle.shapes:
            self._counters["solve_cache_hits"] += 1
            self.registry.counter("session.solve_cache_hits").inc()
        else:
            self._counters["solve_cache_misses"] += 1
            self.registry.counter("session.solve_cache_misses").inc()
            handle.shapes.add(shape)
        self._counters["solves"] += 1
        self.registry.counter("session.solves").inc()
        # the span (and the per-solve wall-clock histogram) covers host-side
        # dispatch + device execution of the already-compiled program; the
        # tracer never enters traced computation, so results are bit-identical
        # with tracing on or off
        with get_tracer().span("sptrsv.solve", pattern=handle.pattern,
                               tag=handle.tag, transpose=transpose, R=R):
            t0 = time.perf_counter()
            x = solver.solve(b)
            self.registry.histogram("session.solve_us").observe(
                (time.perf_counter() - t0) * 1e6)
        return x

    def executor(self, handle: SpTRSVHandle, *, transpose: bool = False
                 ) -> DistributedSolver:
        """The compiled :class:`DistributedSolver` for one sweep direction,
        building plan + executor lazily on first use (the transpose executor
        is an extension of the same analysis, not a second one)."""
        solver = handle.solvers.get(transpose)
        if solver is None:
            solver = DistributedSolver(self.plan(handle, transpose=transpose),
                                       self.mesh)
            handle.solvers[transpose] = solver
        return solver

    def plan(self, handle: SpTRSVHandle, *, transpose: bool = False) -> Plan:
        """Current numeric plan for the handle (forward plans reuse the
        analysis partition; transpose plans analyse the reversed structure
        once, lazily)."""
        if transpose:
            if handle.tplan is None:
                if self.plan_store is not None:
                    handle.tplan = self.plan_store.load(
                        handle.matrix, self.n_devices, handle.options,
                        transpose=True)
                if handle.tplan is not None:
                    self._counters["plan_store_hits"] += 1
                    self.registry.counter("session.plan_store_hits").inc()
                else:
                    handle.tplan = build_plan(handle.matrix, self.n_devices,
                                              handle.config, transpose=True,
                                              verify=handle.options.verify)
                    self._counters["transpose_extensions"] += 1
                    self._store_save(handle, handle.tplan)
            return handle.tplan
        if handle.plan is None:
            handle.plan = build_plan(handle.matrix, self.n_devices,
                                     handle.config, part=handle.part,
                                     verify=handle.options.verify)
            self._store_save(handle, handle.plan)
        return handle.plan

    # -- introspection ----------------------------------------------------

    def dispatch_stats(self, handle: SpTRSVHandle) -> dict:
        """Core dispatch counts for the handle's forward plan, plus the
        recorded auto-tuning decision when auto mode ran."""
        stats = dict(dispatch_stats(self.plan(handle)))
        stats["plan_store_hit"] = handle.plan_store_hit
        if handle.auto is not None:
            d = handle.auto
            stats["auto"] = {
                "chosen": d.chosen, "mode": d.mode,
                "scores": dict(d.scores), "probe_us": dict(d.probe_us),
                "compile_us": dict(d.compile_us),
                "probe_overhead_us": d.probe_overhead_us,
            }
        return stats

    def stats(self) -> dict:
        """Counter snapshot incl. the cache hit rate over analyse + solve
        (symbolic-analysis reuse across handles counts as hits too)."""
        c = dict(self._counters)
        hits = (c.get("analysis_hits", 0) + c.get("solve_cache_hits", 0)
                + c.get("symbolic_hits", 0) + c.get("plan_store_hits", 0))
        misses = c.get("analyses", 0) + c.get("solve_cache_misses", 0)
        c["cache_hit_rate"] = hits / (hits + misses) if hits + misses else 0.0
        return c

    def metrics_snapshot(self, handle: SpTRSVHandle | None = None) -> dict:
        """One JSON-safe view over the session's registry: runtime counters
        and the solve wall-clock histogram, the derived cache hit rate, and —
        given a handle — that handle's plan-static dispatch/cut gauges plus
        recorded auto probe/compile timings (mirrored into the registry so a
        single sink sees everything)."""
        self.registry.gauge("session.cache_hit_rate").set(
            self.stats()["cache_hit_rate"])
        if handle is not None:
            record_plan_metrics(self.registry, self.plan(handle))
            if handle.auto is not None:
                d = handle.auto
                self.registry.gauge("auto.probe_overhead_us").set(
                    d.probe_overhead_us)
                for combo, us in d.probe_us.items():
                    self.registry.gauge(
                        "auto.probe_us." + "/".join(combo)).set(us)
                for combo, us in d.compile_us.items():
                    self.registry.gauge(
                        "auto.compile_us." + "/".join(combo)).set(us)
        return self.registry.snapshot()
