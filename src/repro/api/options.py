"""Typed solver options for the session API.

:class:`PlanOptions` replaces the stringly :class:`repro.core.SolverConfig`
as the front-door configuration object: every mode is an enum (invalid values
raise ``ValueError`` naming the valid choices at construction time, not deep
inside plan tracing), and each of ``sched``/``comm``/``kernel`` additionally
accepts :data:`AUTO` — the context then scores the candidate combinations
with the calibrated cost model (and optional measured probe solves) instead
of making the caller guess which execution mode fits the matrix.

Raw strings are still accepted everywhere and coerced, so
``PlanOptions(comm="zerocopy")`` and ``PlanOptions(comm=Comm.ZEROCOPY)`` are
the same thing, and a legacy ``SolverConfig`` converts losslessly in both
directions (:meth:`PlanOptions.from_config` / :meth:`PlanOptions.to_config`).
"""
from __future__ import annotations

import dataclasses
import enum

from repro.core.partition import STRATEGIES
from repro.core.solver import COMM_MODES, SCHED_MODES, SolverConfig
from repro.kernels.ops import BACKENDS

AUTO = "auto"


def _mode_enum(name: str, values: tuple) -> type:
    """str-Enum over the engine's mode tuple — the core tuples stay the single
    source of valid modes; the enums can never drift from them."""
    return enum.Enum(name, {v.upper(): v for v in values}, type=str)


Sched = _mode_enum("Sched", SCHED_MODES + (AUTO,))
Comm = _mode_enum("Comm", COMM_MODES + (AUTO,))
PartitionStrategy = _mode_enum("PartitionStrategy", STRATEGIES)
# "default" = platform default (pallas on TPU, reference elsewhere)
KernelBackend = _mode_enum("KernelBackend", ("default",) + BACKENDS + (AUTO,))


def _coerce(enum_cls, value, field: str, *, allow_auto: bool = False):
    """Coerce a raw string (or enum) into ``enum_cls``, with an eager,
    choice-naming ``ValueError`` — the satellite fix for mode typos that used
    to surface as obscure failures deep inside plan construction."""
    if value is None and enum_cls is KernelBackend:
        return KernelBackend.DEFAULT
    try:
        member = enum_cls(value.value if isinstance(value, enum.Enum) else str(value))
    except ValueError:
        member = None
    if member is None or (member.value == AUTO and not allow_auto):
        valid = [m.value for m in enum_cls
                 if allow_auto or m.value != AUTO]
        raise ValueError(
            f"invalid {field}: {value!r} (valid choices: {', '.join(valid)})"
        )
    return member


@dataclasses.dataclass(frozen=True)
class PlanOptions:
    """Typed, validated options for one analyse/factorize/solve session.

    ``sched``/``comm``/``kernel`` accept ``"auto"``; :class:`PartitionStrategy`
    stays explicit because the partition *is* the analysis (candidates under
    auto mode share one partition, so auto-tuning never re-analyses).
    """

    block_size: int = 32
    sched: Sched = Sched.LEVELSET
    comm: Comm = Comm.ZEROCOPY
    partition: PartitionStrategy = PartitionStrategy.TASKPOOL
    kernel: KernelBackend = KernelBackend.DEFAULT
    tasks_per_device: int = 8
    gemv_group: int = 0
    rhs_hint: int = 1  # expected RHS panel width, feeds cost model + probes
    # dagpart merge heuristic knobs (see core.partition.merge_levels):
    merge_width: int = 64  # per-device row budget of one merged superstep
    merge_cost: float = 0.0  # narrow-level cost cap; 0 = calibrated threshold
    calibrate_cost: bool = False  # calibrate cost weights via hlo_cost
    probe_solves: int = 0  # >0: measure each auto candidate this many times
    # static plan verification level ("basic"/"contracts"/"strict") applied to
    # every plan this session builds; None defers to the REPRO_VERIFY env var
    verify: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "sched", _coerce(Sched, self.sched, "sched", allow_auto=True))
        object.__setattr__(self, "comm", _coerce(Comm, self.comm, "comm", allow_auto=True))
        object.__setattr__(
            self, "partition", _coerce(PartitionStrategy, self.partition, "partition")
        )
        object.__setattr__(
            self, "kernel", _coerce(KernelBackend, self.kernel, "kernel", allow_auto=True)
        )
        for name, lo in (("block_size", 1), ("tasks_per_device", 1),
                         ("rhs_hint", 1), ("probe_solves", 0), ("gemv_group", 0),
                         ("merge_width", 1)):
            if int(getattr(self, name)) < lo:
                raise ValueError(f"{name} must be >= {lo}, got {getattr(self, name)}")
        if float(self.merge_cost) < 0:
            raise ValueError(f"merge_cost must be >= 0, got {self.merge_cost}")
        if self.verify is not None:
            from repro.verify import LEVELS

            if self.verify not in LEVELS:
                raise ValueError(
                    f"invalid verify: {self.verify!r} "
                    f"(valid choices: {', '.join(LEVELS)})"
                )

    @property
    def is_auto(self) -> bool:
        return Sched.AUTO == self.sched or Comm.AUTO == self.comm \
            or KernelBackend.AUTO == self.kernel

    @classmethod
    def auto(cls, **overrides) -> "PlanOptions":
        """All three execution dimensions auto-tuned; probes on by default."""
        overrides.setdefault("sched", Sched.AUTO)
        overrides.setdefault("comm", Comm.AUTO)
        overrides.setdefault("kernel", KernelBackend.AUTO)
        overrides.setdefault("probe_solves", 2)
        return cls(**overrides)

    @classmethod
    def from_config(cls, config: SolverConfig) -> "PlanOptions":
        return cls(
            block_size=config.block_size, sched=config.sched, comm=config.comm,
            partition=config.partition, kernel=config.kernel_backend,
            tasks_per_device=config.tasks_per_device, gemv_group=config.gemv_group,
            rhs_hint=config.rhs_hint, merge_width=config.merge_width,
            merge_cost=config.merge_cost, calibrate_cost=config.calibrate_cost,
        )

    def to_config(self, *, sched: str | None = None, comm: str | None = None,
                  kernel: str | None = None) -> SolverConfig:
        """Resolve to the concrete engine config; auto dimensions must be
        supplied by the tuner via the keyword overrides."""
        sched = sched or self.sched.value
        comm = comm or self.comm.value
        kernel = kernel if kernel is not None else self.kernel.value
        if AUTO in (sched, comm, kernel):
            raise ValueError("auto options must be resolved before planning "
                             f"(sched={sched!r}, comm={comm!r}, kernel={kernel!r})")
        return SolverConfig(
            block_size=self.block_size, comm=comm, sched=sched,
            partition=self.partition.value, tasks_per_device=self.tasks_per_device,
            kernel_backend=None if kernel == KernelBackend.DEFAULT.value else kernel,
            gemv_group=self.gemv_group, rhs_hint=self.rhs_hint,
            merge_width=self.merge_width, merge_cost=self.merge_cost,
            calibrate_cost=self.calibrate_cost,
        )


def as_options(options) -> PlanOptions:
    """Accept :class:`PlanOptions`, a legacy :class:`SolverConfig`, or None."""
    if options is None:
        return PlanOptions()
    if isinstance(options, PlanOptions):
        return options
    if isinstance(options, SolverConfig):
        return PlanOptions.from_config(options)
    raise TypeError(f"expected PlanOptions or SolverConfig, got {type(options)!r}")
