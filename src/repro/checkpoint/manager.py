"""Fault-tolerant checkpointing: atomic commit, resume, elastic re-shard.

Layout (one directory per step):
    <root>/step_000123.tmp/   -> written fully, fsync'd
    <root>/step_000123/       -> atomic rename marks the commit
    <root>/LATEST             -> text file with the last committed step

Arrays are written as a flat .npz keyed by pytree path plus a JSON manifest
(step, mesh shape, config name). Restore re-shards onto the *current* mesh:
because save materializes global arrays, a job restarted with a different
device count / mesh shape simply re-shards at load (elastic scaling).
At real pod scale this layer would sit on tensorstore/OCDBT; the commit
protocol (tmp dir + rename + LATEST) is the part the framework owns.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        a = np.asarray(leaf)
        if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
            a = a.astype(np.float32)  # npz can't round-trip ml_dtypes; restore
            # casts back to the example leaf dtype (bf16 -> f32 is lossless)
        flat[key] = a
    return flat


def _unflatten_into(tree, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), leaves)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def save(self, step: int, params, opt_state, meta: dict | None = None) -> str:
        tmp = self._dir(step) + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
        manifest = {"step": step, **(meta or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = self._dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.root, "LATEST.tmp"), os.path.join(self.root, "LATEST"))
        self._gc()
        return final

    def latest_step(self) -> int | None:
        p = os.path.join(self.root, "LATEST")
        if not os.path.exists(p):
            return None
        step = int(open(p).read().strip())
        return step if os.path.exists(self._dir(step)) else None

    def restore(self, step: int, example_params, example_opt, *, shardings=None):
        """Load and (re-)shard onto the current mesh via device_put."""
        d = self._dir(step)
        params = _unflatten_into(
            example_params, dict(np.load(os.path.join(d, "params.npz")))
        )
        opt = _unflatten_into(
            example_opt, dict(np.load(os.path.join(d, "opt_state.npz")))
        )
        if shardings is not None:
            params = jax.device_put(params, shardings[0])
            opt = jax.device_put(opt, shardings[1])
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return params, opt, manifest

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
