"""Version-compatibility shims for the jax API surface.

The repo targets the modern API (``jax.shard_map``, ``jax.make_mesh`` with
``axis_types=jax.sharding.AxisType.Auto``); pinned containers ship jax 0.4.x
where ``shard_map`` still lives in ``jax.experimental`` and ``AxisType`` does
not exist. Every mesh construction and every ``shard_map`` in the repo routes
through this module so version skew is handled in exactly one place.
"""
from __future__ import annotations

import numpy as np

import jax


def make_mesh(axis_shapes, axis_names, *, devices=None) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with Auto axis types where supported, plain otherwise."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names), devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)),
        )
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), devices=devices)
    # pre-0.4.35: construct the Mesh directly
    shape = tuple(axis_shapes)
    n = int(np.prod(shape))
    devs = np.asarray(devices if devices is not None else jax.devices()[:n])
    return jax.sharding.Mesh(devs.reshape(shape), tuple(axis_names))


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized: newer jax returns a dict,
    0.4.x returns a one-element list of dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def set_mesh(mesh):
    """Ambient-mesh context manager: ``jax.set_mesh`` on new jax; on 0.4.x the
    Mesh object is itself the context manager that sets the physical mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_mesh():
    """The ambient mesh set by :func:`set_mesh`, or None when unset/empty."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        m = jax.sharding.get_abstract_mesh()
        return None if m is None or m.empty else m
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` (replication checks off) across jax versions."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
