"""Architecture registry + the assigned input-shape cells.

``--arch <id>`` resolution for launchers, plus the four LM shape cells:
  train_4k     seq 4096,   global_batch 256  -> train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill (serve)
  decode_32k   seq 32768,  global_batch 128  -> serve_step (1 token, KV cache)
  long_500k    seq 524288, global_batch 1    -> serve_step; sub-quadratic only

Skip rules (DESIGN.md §4): ``long_500k`` only for subquadratic archs
(zamba2-7b, falcon-mamba-7b); all archs here are decoder-bearing so decode
cells apply everywhere.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "zamba2-7b",
    "seamless-m4t-medium",
    "llama4-maverick-400b-a17b",
    "arctic-480b",
    "falcon-mamba-7b",
    "granite-34b",
    "gemma2-2b",
    "llama3.2-1b",
    "yi-6b",
    "internvl2-1b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _env_overrides() -> dict:
    """REPRO_CFG_OVERRIDES="ssm_tp=false,ssm_chunk=512" — hillclimb A/B knob."""
    import os

    raw = os.environ.get("REPRO_CFG_OVERRIDES", "")
    out = {}
    for kv in filter(None, raw.split(",")):
        k, v = kv.split("=")
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = float(v)
    return out


def get_config(arch: str) -> ModelConfig:
    cfg = importlib.import_module(_MODULES[arch]).CONFIG
    ov = _env_overrides()
    return dataclasses.replace(cfg, **ov) if ov else cfg


def get_reduced(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).reduced()


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k context needs sub-quadratic attention"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
