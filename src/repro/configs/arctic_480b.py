"""arctic-480b — 128-expert top-2 MoE with parallel dense residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000. Every layer: attention + (top-2 of 128 experts ∥
dense residual MLP), the arctic dense-MoE hybrid.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168, n_heads=56,
    n_kv=8, d_ff=4864, vocab=32000, head_dim=128, pattern="E", n_experts=128,
    top_k=2, moe_dense_ff=4864, tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, n_experts=4, moe_dense_ff=128,
    )
