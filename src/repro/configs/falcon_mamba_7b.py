"""falcon-mamba-7b — pure Mamba1 (attention-free) decoder.

[arXiv:2410.05355; unverified] 64L d_model=4096 d_ff=0 vocab=65024
ssm_state=16, d_inner=8192. Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv=1, d_ff=0, vocab=65024, pattern="M", ssm_state=16,
    d_inner_mult=2, subquadratic=True, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, vocab=256, ssm_state=8, ssm_chunk=16
    )
