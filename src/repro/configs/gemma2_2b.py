"""gemma2-2b — alternating local(4k sliding)/global attention + logit softcaps.

[arXiv:2408.00118; hf] 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Pattern ``LA``: sliding-window layer then global layer; attention logits
soft-capped at 50, final logits at 30.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense", n_layers=26, d_model=2304, n_heads=8,
    n_kv=4, d_ff=9216, vocab=256000, head_dim=256, pattern="LA",
    sliding_window=4096, softcap=50.0, final_softcap=30.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, sliding_window=16,
    )
