"""granite-34b — deep MQA code model (GPT-BigCode style, non-gated GELU MLP).

[arXiv:2405.04324; hf] 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense", n_layers=88, d_model=6144, n_heads=48,
    n_kv=1, d_ff=24576, vocab=49152, head_dim=128, pattern="A",
    mlp_gated=False, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv=1, head_dim=16,
        d_ff=128, vocab=256,
    )
