"""internvl2-1b — VLM: InternViT frontend (STUB) + qwen2-0.5b-class LM backbone.

[arXiv:2404.16821; hf] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The vision frontend is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings concatenated with token embeddings (B, S, d).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896, n_heads=14,
    n_kv=2, d_ff=4864, vocab=151655, head_dim=64, pattern="A",
    input_kind="embeddings", tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256,
    )
