"""llama3.2-1b — small llama3 dense decoder.

[hf:meta-llama/Llama-3.2-1B; unverified] 16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048, n_heads=32,
    n_kv=8, d_ff=8192, vocab=128256, head_dim=64, pattern="A",
    rope_theta=500000.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256,
    )
