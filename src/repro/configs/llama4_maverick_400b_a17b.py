"""llama4-maverick-400b-a17b — interleaved-MoE decoder, early fusion.

[hf:meta-llama/Llama-4-*; unverified] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1. Pattern ``DE``: alternating
dense / MoE FFN layers (llama4's interleaved MoE) — total ≈395B params,
≈17B active per token, matching the 400b-a17b name.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv=8, d_ff=8192, vocab=202048, head_dim=128, pattern="DE",
    n_experts=128, top_k=1, rope_theta=500000.0, tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, n_experts=4,
    )
