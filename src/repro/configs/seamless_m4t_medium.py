"""seamless-m4t-medium — encoder-decoder multimodal (audio) transformer.

[arXiv:2308.11596; hf] 12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
Audio frontend is a STUB: ``input_specs`` feeds precomputed frame embeddings
to the encoder; the decoder consumes tokens with cross-attention.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=12, d_model=1024,
    n_heads=16, n_kv=16, d_ff=4096, vocab=256206, head_dim=64, pattern="C",
    enc_layers=12, enc_pattern="A", enc_seq=1536, input_kind="tokens",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=256, enc_layers=2, enc_seq=24,
    )
