"""yi-6b — llama-architecture GQA dense decoder.

[arXiv:2403.04652; hf] 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv=4, d_ff=11008, vocab=64000, head_dim=128, pattern="A",
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256,
    )
