"""zamba2-7b — hybrid Mamba2 backbone + shared attention block.

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000 ssm_state=64. Pattern: five Mamba2 (SSD) blocks then the SHARED
attention+MLP block (one parameter set reused at every ``H`` position).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv=32, d_ff=14336, vocab=32000, head_dim=112, pattern="SSSSSH",
    ssm_state=64, mamba_headdim=64, subquadratic=True, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=12, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=8, mamba_headdim=16, ssm_chunk=16,
    )
