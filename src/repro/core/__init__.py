"""The paper's primary contribution: distributed zero-copy SpTRSV."""
from repro.core.analysis import in_degrees, level_sets, metrics
from repro.core.blocking import BlockStructure, build_blocks, pad_rhs, unpad_x
from repro.core.partition import (
    Partition,
    cut_stats,
    make_partition,
    merge_levels,
    remote_source_levels,
)
from repro.core.solver import (
    AXIS,
    DistributedSolver,
    Plan,
    SolverConfig,
    build_plan,
    dispatch_stats,
    fused_segments,
    fused_streaming,
    fused_vmem_bytes,
    refresh_plan,
    schedule_table_bytes,
    solve_local,
    sptrsv,
    step_offsets,
    step_widths,
    stream_dma_bytes_per_solve,
    stream_vmem_limit,
    streamed_stores,
)
