"""The paper's primary contribution: distributed zero-copy SpTRSV."""
from repro.core.analysis import in_degrees, level_sets, metrics
from repro.core.blocking import BlockStructure, build_blocks, pad_rhs, unpad_x
from repro.core.partition import Partition, cut_stats, make_partition
from repro.core.solver import (
    AXIS,
    DistributedSolver,
    Plan,
    SolverConfig,
    build_plan,
    dispatch_stats,
    fused_segments,
    refresh_plan,
    solve_local,
    sptrsv,
)
