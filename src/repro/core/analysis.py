"""Dependency analysis for SpTRSV (host side, numpy).

Mirrors the paper's two preprocessing flavours:
* ``in_degrees`` — the cheap O(nnz) counter pass used by the synchronization-free
  algorithm (paper §II-C / Alg. 2 lines 6–9, Alg. 3 lines 13–15);
* ``level_sets`` — the classical level-set (Naumov-style) analysis used by the
  level-scheduled baseline (paper §II-B, Fig. 1).

Also computes the paper's scalability metrics (§VI-D):
``dependency = nnz/n`` and ``parallelism = n/#levels``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.matrix import CSR


def in_degrees(a: CSR) -> np.ndarray:
    """Unfinished-dependency counters: off-diagonal nnz per row."""
    return (np.diff(a.row_ptr) - 1).astype(np.int32)


def level_of_rows(a: CSR) -> np.ndarray:
    """lvl[i] = 1 + max(lvl[j] : l_ij != 0, j < i), lvl = 0 for independent rows.

    Single ascending sweep (row i only references j < i). Vectorized per row
    via np.maximum.reduceat over the strictly-lower entries.
    """
    n = a.n
    lvl = np.zeros(n, dtype=np.int32)
    row_ptr, col_idx = a.row_ptr, a.col_idx
    for i in range(n):
        lo, hi = row_ptr[i], row_ptr[i + 1] - 1  # exclude diagonal (last in row)
        if hi > lo:
            lvl[i] = lvl[col_idx[lo:hi]].max() + 1
    return lvl


@dataclasses.dataclass(frozen=True)
class LevelSchedule:
    """Rows grouped by level: rows ``order[level_ptr[t]:level_ptr[t+1]]`` form level t."""

    n_levels: int
    level_ptr: np.ndarray  # (n_levels+1,)
    order: np.ndarray  # (n,) row ids sorted by level (stable)
    level_of: np.ndarray  # (n,)


def level_sets(a: CSR) -> LevelSchedule:
    lvl = level_of_rows(a)
    n_levels = int(lvl.max()) + 1 if a.n else 0
    order = np.argsort(lvl, kind="stable").astype(np.int32)
    counts = np.bincount(lvl, minlength=n_levels)
    level_ptr = np.zeros(n_levels + 1, dtype=np.int64)
    np.cumsum(counts, out=level_ptr[1:])
    return LevelSchedule(n_levels=n_levels, level_ptr=level_ptr, order=order, level_of=lvl)


@dataclasses.dataclass(frozen=True)
class MatrixMetrics:
    n: int
    nnz: int
    n_levels: int
    dependency: float  # nnz / n        (paper §VI-D)
    parallelism: float  # n / #levels   (paper §VI-D / Table I)


def metrics(a: CSR, sched: LevelSchedule | None = None) -> MatrixMetrics:
    sched = sched or level_sets(a)
    return MatrixMetrics(
        n=a.n,
        nnz=a.nnz,
        n_levels=sched.n_levels,
        dependency=a.nnz / max(1, a.n),
        parallelism=a.n / max(1, sched.n_levels),
    )
