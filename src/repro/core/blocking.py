"""B×B dense-block tiling of a sparse lower-triangular matrix.

TPU adaptation of the paper's scalar component model (DESIGN.md §2): scalar
dependency chains are hostile to the VPU/MXU, so we lift the dependency graph
to the *block quotient graph*. Block-row ``bi`` owns components
``[bi*B, (bi+1)*B)``; the diagonal tile is solved by a dense block-TRSV kernel
and each off-diagonal tile ``(bi, bj)`` contributes an MXU GEMV update.
All paper concepts (in-degree, level-sets, task partitioning, boundary
exchange) then operate on block-rows instead of components.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.matrix import CSR


@dataclasses.dataclass(frozen=True)
class BlockStructure:
    """Dense-tile block-sparse view of lower-triangular L (padded to nb*B)."""

    n: int  # original dimension
    B: int  # tile size
    nb: int  # number of block rows = ceil(n/B)
    diag: np.ndarray  # (nb, B, B) dense diagonal tiles (unit-padded)
    off_rows: np.ndarray  # (m,) block-row id of each strictly-lower tile
    off_cols: np.ndarray  # (m,) block-col id of each strictly-lower tile
    off_tiles: np.ndarray  # (m, B, B) dense tile values
    block_level: np.ndarray  # (nb,) level of each block row in the quotient DAG
    block_indeg: np.ndarray  # (nb,) #distinct predecessor tiles per block row

    @property
    def n_tiles(self) -> int:
        return int(self.off_rows.shape[0])

    @property
    def n_block_levels(self) -> int:
        return int(self.block_level.max()) + 1 if self.nb else 0


def _assemble_tiles(a: CSR, B: int, nb: int):
    """Numeric tile assembly: ``(diag, off_tiles, tile_keys)``.

    The single source of the dense-tile value layout, shared by
    :func:`build_blocks` and :func:`refresh_block_values` — the refresh
    path's bit-identity guarantee is by construction, not by keeping two
    copies in sync. ``tile_keys`` is the sorted ``brow * nb + bcol`` id per
    strictly-lower tile.
    """
    rows = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.row_ptr))
    cols = a.col_idx.astype(np.int64)
    vals = a.val
    brow, bcol = rows // B, cols // B

    # --- diagonal tiles ---
    diag = np.zeros((nb, B, B), dtype=np.float32)
    eye_idx = np.arange(B)
    diag[:, eye_idx, eye_idx] = 1.0  # padding rows become identity (inert)
    dmask = brow == bcol
    diag[brow[dmask], rows[dmask] % B, cols[dmask] % B] = vals[dmask]

    # --- strictly-lower tiles (dense) ---
    omask = ~dmask
    key = brow[omask] * nb + bcol[omask]
    uniq, inv = np.unique(key, return_inverse=True)
    off_tiles = np.zeros((uniq.shape[0], B, B), dtype=np.float32)
    off_tiles[inv, rows[omask] % B, cols[omask] % B] = vals[omask]
    return diag, off_tiles, uniq


def build_blocks(a: CSR, B: int) -> BlockStructure:
    nb = -(-a.n // B)
    diag, off_tiles, uniq = _assemble_tiles(a, B, nb)
    off_rows = (uniq // nb).astype(np.int32)
    off_cols = (uniq % nb).astype(np.int32)

    # --- quotient-graph analysis (block in-degree & level-sets) ---
    indeg = np.bincount(off_rows, minlength=nb).astype(np.int32)
    lvl = np.zeros(nb, dtype=np.int32)
    order = np.argsort(off_rows, kind="stable")
    sr, sc = off_rows[order], off_cols[order]
    ptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(np.bincount(sr, minlength=nb), out=ptr[1:])
    for bi in range(nb):
        lo, hi = ptr[bi], ptr[bi + 1]
        if hi > lo:
            lvl[bi] = lvl[sc[lo:hi]].max() + 1
    return BlockStructure(
        n=a.n, B=B, nb=nb, diag=diag, off_rows=off_rows, off_cols=off_cols,
        off_tiles=off_tiles, block_level=lvl, block_indeg=indeg,
    )


def refresh_block_values(bs: BlockStructure, a: CSR) -> BlockStructure:
    """New :class:`BlockStructure` carrying ``a``'s numeric values on ``bs``'s
    exact tile pattern — the numeric half of :func:`build_blocks` without the
    quotient-graph analysis (levels/in-degrees are pattern properties and are
    reused). Raises ``ValueError`` when ``a``'s block pattern differs.
    """
    B, nb = bs.B, bs.nb
    if a.n != bs.n:
        raise ValueError(f"matrix size changed: n={a.n}, analysis has n={bs.n}")
    diag, off_tiles, uniq = _assemble_tiles(a, B, nb)
    if not np.array_equal(
        uniq, bs.off_rows.astype(np.int64) * nb + bs.off_cols.astype(np.int64)
    ):
        raise ValueError(
            "sparsity pattern mismatch: numeric refresh requires the same "
            "tile pattern the analysis was built on"
        )
    return dataclasses.replace(bs, diag=diag, off_tiles=off_tiles)


def pad_rhs(b: np.ndarray, bs: BlockStructure) -> np.ndarray:
    """(n,) -> (nb, B) block layout; (n, k) RHS panels -> (nb, B, k)."""
    b = np.asarray(b, dtype=np.float32)
    out = np.zeros((bs.nb * bs.B,) + b.shape[1:], dtype=np.float32)
    out[: bs.n] = b
    return out.reshape((bs.nb, bs.B) + b.shape[1:])


def unpad_x(xb: np.ndarray, bs: BlockStructure) -> np.ndarray:
    xb = np.asarray(xb)
    return xb.reshape((-1,) + xb.shape[2:])[: bs.n]
