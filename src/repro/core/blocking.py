"""B×B dense-block tiling of a sparse lower-triangular matrix.

TPU adaptation of the paper's scalar component model (DESIGN.md §2): scalar
dependency chains are hostile to the VPU/MXU, so we lift the dependency graph
to the *block quotient graph*. Block-row ``bi`` owns components
``[bi*B, (bi+1)*B)``; the diagonal tile is solved by a dense block-TRSV kernel
and each off-diagonal tile ``(bi, bj)`` contributes an MXU GEMV update.
All paper concepts (in-degree, level-sets, task partitioning, boundary
exchange) then operate on block-rows instead of components.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.matrix import CSR


@dataclasses.dataclass(frozen=True)
class BlockStructure:
    """Dense-tile block-sparse view of lower-triangular L (padded to nb*B)."""

    n: int  # original dimension
    B: int  # tile size
    nb: int  # number of block rows = ceil(n/B)
    diag: np.ndarray  # (nb, B, B) dense diagonal tiles (unit-padded)
    off_rows: np.ndarray  # (m,) block-row id of each strictly-lower tile
    off_cols: np.ndarray  # (m,) block-col id of each strictly-lower tile
    off_tiles: np.ndarray  # (m, B, B) dense tile values
    block_level: np.ndarray  # (nb,) level of each block row in the quotient DAG
    block_indeg: np.ndarray  # (nb,) #distinct predecessor tiles per block row

    @property
    def n_tiles(self) -> int:
        return int(self.off_rows.shape[0])

    @property
    def n_block_levels(self) -> int:
        return int(self.block_level.max()) + 1 if self.nb else 0


def build_blocks(a: CSR, B: int) -> BlockStructure:
    nb = -(-a.n // B)
    n_pad = nb * B
    rows = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.row_ptr))
    cols = a.col_idx.astype(np.int64)
    vals = a.val
    brow, bcol = rows // B, cols // B

    # --- diagonal tiles ---
    diag = np.zeros((nb, B, B), dtype=np.float32)
    eye_idx = np.arange(B)
    diag[:, eye_idx, eye_idx] = 1.0  # padding rows become identity (inert)
    dmask = brow == bcol
    diag[brow[dmask], rows[dmask] % B, cols[dmask] % B] = vals[dmask]

    # --- strictly-lower tiles (dense) ---
    omask = ~dmask
    o_brow, o_bcol = brow[omask], bcol[omask]
    key = o_brow * nb + o_bcol
    uniq, inv = np.unique(key, return_inverse=True)
    m = uniq.shape[0]
    off_tiles = np.zeros((m, B, B), dtype=np.float32)
    off_tiles[inv, rows[omask] % B, cols[omask] % B] = vals[omask]
    off_rows = (uniq // nb).astype(np.int32)
    off_cols = (uniq % nb).astype(np.int32)

    # --- quotient-graph analysis (block in-degree & level-sets) ---
    indeg = np.bincount(off_rows, minlength=nb).astype(np.int32)
    lvl = np.zeros(nb, dtype=np.int32)
    order = np.argsort(off_rows, kind="stable")
    sr, sc = off_rows[order], off_cols[order]
    ptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(np.bincount(sr, minlength=nb), out=ptr[1:])
    for bi in range(nb):
        lo, hi = ptr[bi], ptr[bi + 1]
        if hi > lo:
            lvl[bi] = lvl[sc[lo:hi]].max() + 1
    del n_pad
    return BlockStructure(
        n=a.n, B=B, nb=nb, diag=diag, off_rows=off_rows, off_cols=off_cols,
        off_tiles=off_tiles, block_level=lvl, block_indeg=indeg,
    )


def pad_rhs(b: np.ndarray, bs: BlockStructure) -> np.ndarray:
    """(n,) -> (nb, B) block layout; (n, k) RHS panels -> (nb, B, k)."""
    b = np.asarray(b, dtype=np.float32)
    out = np.zeros((bs.nb * bs.B,) + b.shape[1:], dtype=np.float32)
    out[: bs.n] = b
    return out.reshape((bs.nb, bs.B) + b.shape[1:])


def unpad_x(xb: np.ndarray, bs: BlockStructure) -> np.ndarray:
    xb = np.asarray(xb)
    return xb.reshape((-1,) + xb.shape[2:])[: bs.n]
