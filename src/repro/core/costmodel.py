"""Calibrated block-op cost weights (ROADMAP "Cost model calibration").

``block_row_cost``'s analytic default says a B×B tile product costs 2× the
diagonal TRSV. This module replaces the guess with a per-backend measurement:
it compiles one representative block TRSV and block GEMV/GEMM through the
actual kernel dispatch (``kernels.ops``), runs the loop-aware HLO analysis
from :mod:`repro.launch.hlo_cost` over the optimized module, and converts the
result into the weights of the minimal multi-RHS cost model

    cost(row, R) = w_solve·R + Σ_tiles (w_tile_mem + w_tile_flop·R)

``w_tile_mem`` is the R-independent tile-load term (a GEMM panel amortizes the
tile fetch across all R systems), ``w_tile_flop`` the per-RHS MXU slope,
fitted from the measured cost at R=1 and R=R_PROBE. Costs combine dot flops
with the HBM-traffic proxy (dot operand/output bytes) at a fixed machine
balance; weights are normalized to ``w_solve = 1``.

HLO that hides its work from the dot-based analysis — ``triangular_solve``
lowers to a LAPACK custom call on CPU, Pallas interpret bodies reduce with
masked sums — reports 0 flops; every term then falls back to its analytic
count, so calibration degrades gracefully to (a rescaled) analytic model
instead of producing nonsense weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.launch import hlo_cost

R_PROBE = 8  # panel width used to fit the per-RHS slope
FLOPS_PER_BYTE = 4.0  # machine balance: one HBM byte ≈ 4 flop-equivalents
MERGE_NARROW_ROWS = 8  # a "narrow" level carries at most ~this many typical rows


def merge_cost_threshold(weights: tuple = (1.0, 1.0, 1.0), R: int = 1) -> float:
    """Busiest-device cost below which a level counts as *narrow* for the
    DAG-partition merge pass (``sched="dagpart"``).

    A level whose critical device does less work than ``MERGE_NARROW_ROWS``
    typical block rows is launch-overhead-bound: the grid step / exchange
    segment costs more than the level's compute, so merging it into the
    neighbouring superstep wins. "Typical row" = one diagonal TRSV plus two
    tile products, priced by the same (calibrated) weights that drive the
    malleable placement — the heuristic sharpens automatically as the
    wall-clock feedback loop refines the weights.
    """
    w_solve, w_tile_mem, w_tile_flop = weights
    unit = w_solve * R + 2.0 * (w_tile_mem + w_tile_flop * R)
    return MERGE_NARROW_ROWS * max(float(unit), 1e-9)


def _measured(fn, *args) -> tuple[float, float]:
    """(dot flops, dot traffic bytes) of the compiled fn at these shapes."""
    txt = jax.jit(fn).lower(*args).compile().as_text()
    r = hlo_cost.analyze(txt)
    return float(r["flops"]), float(r["dot_bytes"])


def _term(flops: float, bytes_: float, analytic_flops: float,
          analytic_bytes: float) -> float:
    f = flops if flops > 0 else analytic_flops
    by = bytes_ if bytes_ > 0 else analytic_bytes
    return f + FLOPS_PER_BYTE * by


def calibrate_weights(B: int = 32, backend: str | None = None, *,
                      feedback: bool = True) -> tuple:
    """(w_solve, w_tile_mem, w_tile_flop) for B×B tiles on ``backend``,
    normalized to w_solve = 1.

    The wall-clock feedback loop takes precedence: when the calibration
    store (:mod:`repro.obs.calibration`) holds enough measured probe-solve
    samples for this (backend, B) to fit trustworthy weights, those fitted
    weights are returned — a ``probe_solves=0`` session inherits timings a
    prior probed session persisted. Otherwise (or with ``feedback=False``)
    the HLO-derived estimate below is used. Both paths return a stable cached
    tuple per (B, backend) until new samples arrive.
    """
    if feedback:
        from repro.obs.calibration import fitted_weights

        w = fitted_weights(B, backend)
        if w is not None:
            return w
    return hlo_weights(B, backend)


@functools.lru_cache(maxsize=None)
def hlo_weights(B: int = 32, backend: str | None = None) -> tuple:
    """The pure HLO-derived weight estimate (no measured feedback), cached
    per (B, backend)."""
    kb = ops.op_backend(backend)
    diag = jnp.eye(B, dtype=jnp.float32)[None]
    vec = jnp.ones((1, B), jnp.float32)
    panel = jnp.ones((1, B, R_PROBE), jnp.float32)

    def trsv(d, r):
        return ops.batched_block_trsv(d, r, backend=kb)

    def gemv(t, x):
        return ops.batched_block_gemv(t, x, backend=kb)

    tile_bytes = B * B * 4
    t_f, t_b = _measured(trsv, diag, vec)
    g1_f, g1_b = _measured(gemv, diag, vec)
    gR_f, gR_b = _measured(gemv, diag, panel)
    # analytic fallbacks: TRSV touches the triangle (B² flops), each product
    # moves the full tile plus in/out vectors
    t1 = _term(t_f, t_b, B * B, tile_bytes + 2 * B * 4)
    g1 = _term(g1_f, g1_b, 2 * B * B, tile_bytes + 2 * B * 4)
    gR = _term(gR_f, gR_b, 2 * B * B * R_PROBE, tile_bytes + 2 * B * R_PROBE * 4)
    w_tile_flop = max(0.0, (gR - g1) / (R_PROBE - 1))
    w_tile_mem = max(0.0, g1 - w_tile_flop)
    return (1.0, w_tile_mem / t1, w_tile_flop / t1)
