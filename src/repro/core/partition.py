"""Workload partitioning across devices — the paper's task-pool model (§V).

Two strategies over *block rows* (the schedulable unit, DESIGN.md §2):

* ``contiguous`` — the paper's baseline: block-rows split into D consecutive
  ranges. Dependencies become unidirectional (device d always waits on
  devices < d), the imbalance the paper identifies.
* ``taskpool``   — the paper's contribution: block-rows grouped into *tasks* of
  ``task_size`` consecutive block-rows, dealt **round-robin** to devices.
  ``tasks_per_device`` is the paper's tunable (Fig. 9 sensitivity).

Also computes the *cut statistics* that drive the zero-copy exchange: a block
row is a **boundary row** iff some tile in that row lives in a column owned by
a different device — only those rows are communicated (DESIGN.md §5.2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.blocking import BlockStructure


@dataclasses.dataclass(frozen=True)
class Partition:
    n_devices: int
    strategy: str  # "contiguous" | "taskpool"
    tasks_per_device: int
    owner: np.ndarray  # (nb,) device owning each block row (and block column)
    boundary: np.ndarray  # (nb,) bool: row receives updates from a remote device

    def local_rows(self, d: int) -> np.ndarray:
        return np.nonzero(self.owner == d)[0].astype(np.int32)


def make_partition(
    bs: BlockStructure,
    n_devices: int,
    strategy: str = "taskpool",
    tasks_per_device: int = 8,
) -> Partition:
    nb = bs.nb
    if strategy == "contiguous":
        per = -(-nb // n_devices)
        owner = np.minimum(np.arange(nb) // per, n_devices - 1).astype(np.int32)
        tasks_per_device = 1
    elif strategy == "taskpool":
        n_tasks = n_devices * tasks_per_device
        task_size = max(1, -(-nb // n_tasks))
        task_of = np.arange(nb) // task_size
        owner = (task_of % n_devices).astype(np.int32)  # round-robin deal (paper §V)
    else:
        raise ValueError(f"unknown partition strategy: {strategy}")

    boundary = np.zeros(nb, dtype=bool)
    remote = owner[bs.off_cols] != owner[bs.off_rows]
    boundary[bs.off_rows[remote]] = True
    return Partition(
        n_devices=n_devices, strategy=strategy, tasks_per_device=tasks_per_device,
        owner=owner, boundary=boundary,
    )


@dataclasses.dataclass(frozen=True)
class CutStats:
    """Communication / balance statistics (feeds bench_comm_volume, Fig-3 analogue)."""

    boundary_rows: int
    boundary_fraction: float
    remote_tiles: int
    remote_tile_fraction: float
    level_imbalance: float  # mean over levels of max_dev_rows / mean_dev_rows


def cut_stats(bs: BlockStructure, part: Partition) -> CutStats:
    remote = part.owner[bs.off_cols] != part.owner[bs.off_rows]
    n_levels = bs.n_block_levels
    # per-level, per-device row counts
    imb = []
    for t in range(n_levels):
        rows_t = np.nonzero(bs.block_level == t)[0]
        if rows_t.size == 0:
            continue
        counts = np.bincount(part.owner[rows_t], minlength=part.n_devices)
        mean = counts.mean()
        if mean > 0:
            imb.append(counts.max() / mean)
    return CutStats(
        boundary_rows=int(part.boundary.sum()),
        boundary_fraction=float(part.boundary.mean()),
        remote_tiles=int(remote.sum()),
        remote_tile_fraction=float(remote.mean()) if remote.size else 0.0,
        level_imbalance=float(np.mean(imb)) if imb else 1.0,
    )
