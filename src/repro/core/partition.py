"""Workload partitioning across devices — the paper's task-pool model (§V).

Three strategies over *block rows* (the schedulable unit, DESIGN.md §2):

* ``contiguous`` — the paper's baseline: block-rows split into D consecutive
  ranges. Dependencies become unidirectional (device d always waits on
  devices < d), the imbalance the paper identifies.
* ``taskpool``   — the paper's contribution: block-rows grouped into *tasks* of
  ``task_size`` consecutive block-rows, dealt **round-robin** to devices.
  ``tasks_per_device`` is the paper's tunable (Fig. 9 sensitivity).
* ``malleable``  — cost-model-driven task pool (paper Fig. 9 direction, plus
  the elasticity line of work): per-block-row cost = diagonal solve + the tile
  updates computed where that block column lives; each *level* is chopped into
  tasks of adaptive size (equal cost, not equal row count) and the tasks are
  placed greedily, largest first (LPT), onto the least-loaded device of that
  level. Ties within a small load slack go to the device that already owns the
  most predecessor tiles, keeping the boundary cut small. Because placement is
  per level, every wavefront is balanced by construction instead of relying on
  the round-robin deal to scatter a level's rows evenly.

Also computes the *cut statistics* that drive the zero-copy exchange: a block
row is a **boundary row** iff some tile in that row lives in a column owned by
a different device — only those rows are communicated (DESIGN.md §5.2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.blocking import BlockStructure

STRATEGIES = ("contiguous", "taskpool", "malleable")


@dataclasses.dataclass(frozen=True)
class Partition:
    n_devices: int
    strategy: str  # one of STRATEGIES
    tasks_per_device: int
    owner: np.ndarray  # (nb,) device owning each block row (and block column)
    boundary: np.ndarray  # (nb,) bool: row receives updates from a remote device

    def local_rows(self, d: int) -> np.ndarray:
        return np.nonzero(self.owner == d)[0].astype(np.int32)


DEFAULT_COST_WEIGHTS = (1.0, 1.0, 1.0)  # (w_solve, w_tile_mem, w_tile_flop)


def block_row_cost(
    bs: BlockStructure,
    *,
    weights: tuple = DEFAULT_COST_WEIGHTS,
    R: int = 1,
) -> np.ndarray:
    """Per-block-row work in block-op units for an R-wide RHS panel.

    Owning row r means one B×B diagonal solve plus one B×B product per tile in
    the row's *column* (tiles live on their column's owner). The minimal
    multi-RHS model splits the tile term into an R-independent load
    (``w_tile_mem`` — GEMM amortizes the tile fetch across the panel) and a
    per-RHS MXU term (``w_tile_flop``):

        cost = w_solve·R + (w_tile_mem + w_tile_flop·R) · tiles_in_column

    The defaults reproduce the analytic 1:2 TRSV:GEMV ratio at R=1
    (``1 + 2·tiles``); calibrated weights come from
    :func:`repro.core.costmodel.calibrate_weights`.
    """
    w_solve, w_tile_mem, w_tile_flop = weights
    col_tiles = np.bincount(bs.off_cols, minlength=bs.nb)
    return w_solve * R + (w_tile_mem + w_tile_flop * R) * col_tiles


def _malleable_owner(
    bs: BlockStructure, n_devices: int, tasks_per_device: int,
    cost_weights: tuple = DEFAULT_COST_WEIGHTS, cost_R: int = 1,
) -> np.ndarray:
    nb, D = bs.nb, n_devices
    owner = np.full(nb, -1, dtype=np.int32)
    cost = block_row_cost(bs, weights=cost_weights, R=cost_R)
    lvl = bs.block_level
    # row -> predecessor block-columns (CSR over tiles), for placement affinity
    order = np.argsort(bs.off_rows, kind="stable")
    pre_cols = bs.off_cols[order]
    pre_ptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(np.bincount(bs.off_rows, minlength=nb), out=pre_ptr[1:])

    for t in range(bs.n_block_levels):
        rows_t = np.nonzero(lvl == t)[0]  # ascending: consecutive rows cluster
        if rows_t.size == 0:
            continue
        # malleable task sizing: chop the level into exactly n_tasks contiguous
        # tasks of (approximately) equal COST — dense rows travel alone, sparse
        # rows pool together. The target is re-derived from the remaining cost
        # so one oversized row cannot starve the trailing tasks.
        size = int(rows_t.size)
        n_tasks = int(min(size, D * tasks_per_device))
        level_cost = cost[rows_t]
        remaining = float(level_cost.sum())
        tasks = []
        i = 0
        for k in range(n_tasks):
            tgt = remaining / (n_tasks - k)
            j = i
            acc = 0.0
            # leave at least one row for each task still to be formed
            cap = size - (n_tasks - k - 1)
            while j < cap and (j == i or acc < tgt):
                acc += level_cost[j]
                j += 1
            tasks.append(rows_t[i:j])
            remaining -= acc
            i = j
        if i < size:  # numerical slack: sweep leftovers into the last task
            tasks[-1] = rows_t[i - tasks[-1].size:]
        task_cost = np.array([cost[tk].sum() for tk in tasks])

        # LPT within the level: heaviest task -> least-loaded device. Within a
        # small load slack of the minimum, prefer (fewest rows this level, most
        # owned predecessor tiles) — count balance is the metric the wavefront
        # pays for, the affinity term keeps the boundary cut small.
        load = np.zeros(D)
        rows_of = np.zeros(D, dtype=np.int64)
        slack = 0.25 * task_cost.mean()
        for i in np.argsort(task_cost, kind="stable")[::-1]:
            tk = tasks[i]
            cand = np.nonzero(load <= load.min() + slack)[0]
            if cand.size > 1:
                cand = cand[rows_of[cand] == rows_of[cand].min()]
            if cand.size > 1:
                pre = np.concatenate(
                    [pre_cols[pre_ptr[r]:pre_ptr[r + 1]] for r in tk]
                ).astype(np.int64)
                own = owner[pre] if pre.size else np.empty(0, np.int32)
                own = own[own >= 0]
                aff = np.bincount(own, minlength=D) if own.size else np.zeros(D, np.int64)
                cand = cand[aff[cand] == aff[cand].max()]
            d = cand[np.argmin(load[cand])]
            owner[tk] = d
            load[d] += task_cost[i]
            rows_of[d] += tk.size
    return owner


def make_partition(
    bs: BlockStructure,
    n_devices: int,
    strategy: str = "taskpool",
    tasks_per_device: int = 8,
    *,
    cost_weights: tuple | None = None,
    cost_R: int = 1,
) -> Partition:
    """``cost_weights``/``cost_R`` feed the malleable strategy's cost model
    (calibrated TRSV:GEMV weights and the expected RHS panel width); the
    row-count strategies ignore them."""
    from repro.obs.trace import get_tracer

    with get_tracer().span("sptrsv.partition", strategy=strategy,
                           n_devices=n_devices, nb=bs.nb) as span:
        part = _make_partition(bs, n_devices, strategy, tasks_per_device,
                               cost_weights=cost_weights, cost_R=cost_R)
        span.set(boundary_rows=int(part.boundary.sum()))
    return part


def _make_partition(
    bs: BlockStructure,
    n_devices: int,
    strategy: str = "taskpool",
    tasks_per_device: int = 8,
    *,
    cost_weights: tuple | None = None,
    cost_R: int = 1,
) -> Partition:
    nb = bs.nb
    if strategy == "contiguous":
        per = -(-nb // n_devices)
        owner = np.minimum(np.arange(nb) // per, n_devices - 1).astype(np.int32)
        tasks_per_device = 1
    elif strategy == "taskpool":
        n_tasks = n_devices * tasks_per_device
        task_size = max(1, -(-nb // n_tasks))
        task_of = np.arange(nb) // task_size
        owner = (task_of % n_devices).astype(np.int32)  # round-robin deal (paper §V)
    elif strategy == "malleable":
        owner = _malleable_owner(
            bs, n_devices, tasks_per_device,
            cost_weights=cost_weights or DEFAULT_COST_WEIGHTS, cost_R=cost_R,
        )
    else:
        raise ValueError(f"unknown partition strategy: {strategy!r} "
                         f"(expected one of {STRATEGIES})")

    boundary = np.zeros(nb, dtype=bool)
    remote = owner[bs.off_cols] != owner[bs.off_rows]
    boundary[bs.off_rows[remote]] = True
    return Partition(
        n_devices=n_devices, strategy=strategy, tasks_per_device=tasks_per_device,
        owner=owner, boundary=boundary,
    )


def remote_source_levels(bs: BlockStructure, part: Partition) -> np.ndarray:
    """(T,) max block level of any *remote* source column feeding each level
    (−1 when every tile landing in the level is device-local).

    This is the legality oracle for superstep merging: level ``t`` may join a
    merged superstep starting at level ``g`` iff ``remote_source_levels[t] <
    g`` — every cross-device contribution into ``t`` then solved in an
    *earlier* superstep, so the exchange at the group's start already carries
    it. Intra-device dependencies are unconstrained: the in-kernel rowsweep
    executes the group's levels in order.
    """
    T = bs.n_block_levels
    mrs = np.full(T, -1, dtype=np.int64)
    if part.n_devices <= 1 or T == 0:
        return mrs
    remote = part.owner[bs.off_cols] != part.owner[bs.off_rows]
    if not remote.any():
        return mrs
    lvl = bs.block_level
    np.maximum.at(mrs, lvl[bs.off_rows[remote]], lvl[bs.off_cols[remote]])
    return mrs


def merge_levels(
    bs: BlockStructure,
    part: Partition,
    *,
    merge_width: int = 64,
    merge_cost: float = 0.0,
    cost_weights: tuple | None = None,
    cost_R: int = 1,
) -> np.ndarray:
    """Greedy DAG-partition merge pass: coarsen the levelset schedule into
    supersteps. Returns ``(n_steps + 1,)`` int32 offsets into the level range
    — superstep ``s`` executes levels ``[off[s], off[s+1])`` in one grid step.

    Level ``t`` joins the running group (started at level ``g``) iff
\
    * **legality** — every remote source into ``t`` solves before ``g``
      (:func:`remote_source_levels`), so the group-start exchange already
      carries it;
    * **narrowness** — both the running group and ``t`` are launch-bound:
      busiest-device cost per level ≤ ``merge_cost`` (0 → calibrated
      :func:`repro.core.costmodel.merge_cost_threshold`). Wide levels keep
      their own superstep — merging them would serialize real parallelism
      inside the kernel's sequential rowsweep;
    * **churn cap** — the busiest device's accumulated row count for the
      group stays ≤ ``merge_width``, bounding per-step schedule slices (and
      the streamed-DMA burst) so merged steps don't blow the VMEM ladder.
    """
    T = bs.n_block_levels
    if T == 0:
        return np.zeros(1, dtype=np.int32)
    weights = cost_weights or DEFAULT_COST_WEIGHTS
    if merge_cost <= 0:
        from repro.core.costmodel import merge_cost_threshold

        merge_cost = merge_cost_threshold(weights, R=cost_R)
    cost = block_row_cost(bs, weights=weights, R=cost_R)
    lvl = bs.block_level
    # busiest-device cost and row count per level
    lvl_cost = np.zeros(T)
    lvl_rows = np.zeros(T, dtype=np.int64)
    for d in range(part.n_devices):
        mine = part.owner == d
        if mine.any():
            lvl_cost = np.maximum(lvl_cost, np.bincount(
                lvl[mine], weights=cost[mine], minlength=T)[:T])
            lvl_rows = np.maximum(lvl_rows, np.bincount(
                lvl[mine], minlength=T)[:T])
    mrs = remote_source_levels(bs, part)

    starts = [0]
    acc_rows = int(lvl_rows[0])
    narrow_run = bool(lvl_cost[0] <= merge_cost)
    for t in range(1, T):
        narrow = bool(lvl_cost[t] <= merge_cost)
        if (narrow and narrow_run and mrs[t] < starts[-1]
                and acc_rows + int(lvl_rows[t]) <= merge_width):
            acc_rows += int(lvl_rows[t])
            continue
        starts.append(t)
        acc_rows = int(lvl_rows[t])
        narrow_run = narrow
    return np.asarray(starts + [T], dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class CutStats:
    """Communication / balance statistics (feeds bench_comm_volume, Fig-3 analogue)."""

    boundary_rows: int
    boundary_fraction: float
    remote_tiles: int
    remote_tile_fraction: float
    level_imbalance: float  # mean over levels of max_dev_rows / mean_dev_rows
    level_cost_imbalance: float  # same, weighted by the block-row cost model


def cut_stats(bs: BlockStructure, part: Partition) -> CutStats:
    remote = part.owner[bs.off_cols] != part.owner[bs.off_rows]
    n_levels = bs.n_block_levels
    cost = block_row_cost(bs)
    # per-level, per-device row counts and cost loads
    imb, cimb = [], []
    for t in range(n_levels):
        rows_t = np.nonzero(bs.block_level == t)[0]
        if rows_t.size == 0:
            continue
        counts = np.bincount(part.owner[rows_t], minlength=part.n_devices)
        mean = counts.mean()
        if mean > 0:
            imb.append(counts.max() / mean)
        loads = np.bincount(part.owner[rows_t], weights=cost[rows_t],
                            minlength=part.n_devices)
        if loads.mean() > 0:
            cimb.append(loads.max() / loads.mean())
    return CutStats(
        boundary_rows=int(part.boundary.sum()),
        boundary_fraction=float(part.boundary.mean()),
        remote_tiles=int(remote.sum()),
        remote_tile_fraction=float(remote.mean()) if remote.size else 0.0,
        level_imbalance=float(np.mean(imb)) if imb else 1.0,
        level_cost_imbalance=float(np.mean(cimb)) if cimb else 1.0,
    )
