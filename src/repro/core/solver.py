"""Distributed SpTRSV — the paper's contribution, TPU-native (DESIGN.md §5).

Execution model
---------------
Block-rows are distributed by a :class:`~repro.core.partition.Partition`
(each device owns block-row *and* block-column ``r`` — the paper's layout
where components x, columns of L and rhs b are co-partitioned). Tiles live on
the owner of their *column*, so an update ``acc[r] += L[r,c] @ x[c]`` is always
computed where ``x[c]`` was produced: the **only** communication is combining
per-device partial accumulators — the paper's read-only model, where each PE
accumulates into its own symmetric-heap array and the owner of a row pulls and
reduces partials right before solving.

Communication modes (paper Fig. 7 scenarios):
* ``unified``  — all-reduce the *full* n-sized accumulator delta every
  superstep (the Unified-Memory analogue: dense, cut-oblivious traffic).
* ``zerocopy`` — exchange only *packed boundary rows*; in ``levelset``
  scheduling each row is exchanged exactly once, lazily, right before its
  level (the NVSHMEM get+warp-reduce analogue: psum of the packed buffer).

Scheduling modes:
* ``levelset`` — host-precomputed block wavefronts (Naumov-style baseline).
* ``dagpart``  — levelset coarsened by the DAG-partition merge pass
  (:func:`repro.core.partition.merge_levels`): consecutive narrow levels fuse
  into one superstep whose in-kernel rowsweep executes intra-step
  dependencies in order — fewer grid steps, fewer exchange segments, smaller
  schedule tables. The micro-level tables stay byte-identical to levelset;
  only ``Plan.step_off`` (and the hoisted exchange slices) differ.
* ``syncfree`` — no level analysis; runtime in-degree counters discover the
  frontier each superstep (the paper's synchronization-free algorithm,
  bulk-synchronous TPU adaptation).

Compacted schedules
-------------------
Levelset schedules are stored *ragged*: one flat array per schedule
(``solve_rows``, ``upd_tiles``, ``ex_rows``) plus per-level offsets
(``lvl_off``). Each level's slice is padded only up to a *bucket width* drawn
from a small ladder (``Plan.buckets``), and the executor compiles one superstep
body per occurring bucket combo, dispatched with ``lax.switch`` — so a level
with 3 rows costs a width-4 superstep instead of the global max width, cutting
the wasted pad flops and pad exchange bytes that a dense ``(T, max)`` layout
burns on skewed level-size distributions.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import warnings

from repro import compat
from repro.core.blocking import BlockStructure, build_blocks, refresh_block_values
from repro.core.partition import (
    STRATEGIES, Partition, make_partition, merge_levels,
)
from repro.kernels import ops
from repro.obs.trace import get_tracer
from repro.sparse.matrix import CSR, reverse_transpose
from repro.kernels.superstep import superstep_call

AXIS = "x"  # device axis name used by the solver

MAX_BUCKETS = 12  # cap on distinct (solve, update, exchange) width combos

COMM_MODES = ("zerocopy", "unified")
SCHED_MODES = ("levelset", "dagpart", "syncfree")
# scheds that execute the compacted levelset tables (dagpart is levelset plus
# a superstep coarsening on top of the same flats)
LEVELSET_SCHEDS = ("levelset", "dagpart")


def _check_choice(name: str, value, valid: tuple) -> None:
    if value not in valid:
        raise ValueError(
            f"invalid {name}: {value!r} (valid choices: {', '.join(valid)})"
        )


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    block_size: int = 32
    comm: str = "zerocopy"  # "zerocopy" | "unified"
    sched: str = "levelset"  # "levelset" | "dagpart" | "syncfree"
    partition: str = "taskpool"  # "taskpool" | "contiguous" | "malleable"
    tasks_per_device: int = 8
    # None -> env/platform default; "reference"/"pallas" pick the per-op kernels
    # for the lax.switch executor; "fused" runs the superstep megakernel
    # (levelset) / frontier-bucketed executor (syncfree); "fused_streamed"
    # additionally streams the diag/tile stores from HBM per level (plain
    # "fused" auto-upgrades to streaming above stream_vmem_limit()).
    kernel_backend: str | None = None
    gemv_group: int = 0
    rhs_hint: int = 1  # expected RHS panel width R, feeds the partition cost model
    calibrate_cost: bool = False  # calibrate cost weights via hlo_cost per backend
    # dagpart merge heuristic knobs (ignored by the other scheds):
    # merge_width caps the busiest device's accumulated rows per merged
    # superstep; merge_cost is the narrow-level cost threshold (0 -> the
    # calibrated costmodel.merge_cost_threshold default)
    merge_width: int = 64
    merge_cost: float = 0.0

    def __post_init__(self):
        # Eager validation at the API boundary: a typo'd mode used to surface
        # as an obscure failure deep inside plan construction or tracing.
        _check_choice("comm", self.comm, COMM_MODES)
        _check_choice("sched", self.sched, SCHED_MODES)
        _check_choice("partition", self.partition, STRATEGIES)
        if self.kernel_backend is not None:
            _check_choice("kernel_backend", self.kernel_backend, ops.BACKENDS)
        for name, lo in (("block_size", 1), ("tasks_per_device", 1), ("rhs_hint", 1),
                         ("merge_width", 1)):
            if int(getattr(self, name)) < lo:
                raise ValueError(f"{name} must be >= {lo}, got {getattr(self, name)}")
        if float(self.merge_cost) < 0:
            raise ValueError(f"merge_cost must be >= 0, got {self.merge_cost}")


@dataclasses.dataclass(frozen=True)
class Plan:
    """Host-built execution plan: everything static for a (matrix, partition)."""

    bs: BlockStructure
    part: Partition
    config: SolverConfig
    n_devices: int
    n_levels: int
    # replicated
    diag: np.ndarray  # (nb+1, B, B) identity at pad slot
    owner: np.ndarray  # (nb+1,) int32, -1 at pad
    indeg: np.ndarray  # (nb+1,) int32 tile in-degree per block row
    ex_rows: np.ndarray  # (E,) ragged rows exchanged per level (levelset/zerocopy)
    ex_boundary: np.ndarray  # (n_boundary or 1,) boundary rows (syncfree/zerocopy)
    # ragged levelset schedules: flat arrays + per-level offsets + width buckets
    lvl_off: np.ndarray  # (T, 3) int32 start of level t in (solve, upd, ex) flats
    lvl_bucket: np.ndarray  # (T,) int32 index into `buckets`
    buckets: tuple  # ((ws, wu, we), ...) level widths, small set (<= MAX_BUCKETS)
    # sharded by leading device axis
    solve_rows: np.ndarray  # (D, S) ragged owned rows per level, pad -1 (levelset)
    upd_tiles: np.ndarray  # (D, U) ragged local tile ids per level, pad ML (levelset)
    local_rows: np.ndarray  # (D, MLR) owned rows, pad nb (syncfree)
    tile_row: np.ndarray  # (D, ML+1) dest block-row per local tile, pad nb
    tile_col: np.ndarray  # (D, ML+1) src block-col per local tile, pad nb
    tiles: np.ndarray  # (D, ML+1, B, B) zero tile at pad slot
    transpose: bool = False  # plan solves a^T x = b (built on reverse_transpose(a))
    # max (rows, tiles) any device schedules in one level — the syncfree runtime
    # frontier can never exceed these (bulk-synchronous sweeps converge
    # level-by-level), so they cap the frontier width ladder
    frontier_caps: tuple = (1, 1)
    # dagpart only: (n_steps+1,) level offsets of the merged supersteps —
    # superstep s runs levels [step_off[s], step_off[s+1]) in one grid step.
    # None (levelset/syncfree) means the identity: one superstep per level.
    step_off: np.ndarray | None = None

    @property
    def n_supersteps(self) -> int:
        """Bulk-synchronous supersteps per solve. Levelset executes one
        superstep per block level; syncfree's runtime frontier discovery also
        converges level-by-level (each superstep solves exactly the rows whose
        in-degree count completed, i.e. the next block level); dagpart merges
        consecutive narrow levels, so it reports the merged step count."""
        if self.step_off is not None:
            return max(0, len(self.step_off) - 1)
        return self.n_levels

    @property
    def n_boundary_rows(self) -> int:
        """Block rows that receive updates from a remote device."""
        return int(self.part.boundary.sum())

    @property
    def comm_bytes_per_solve(self) -> int:
        """Predicted collective payload bytes for one solve (one device's
        share) — the payload the executors actually put on the wire. The old
        global pad-to-max sentinel slots are gone (each boundary row is pulled
        once, at its level's *bucket* width, so only the bucket slack rides
        along), and single-device plans — which execute no collectives at
        all — report exactly 0."""
        if self.n_devices == 1:
            return 0
        B = self.bs.B
        itemsize = 4
        if self.config.comm == "unified":
            # an empty cut means every update is device-local: the executors
            # skip the dense psums entirely (hb.exchange.degenerate)
            if self.n_boundary_rows == 0:
                return 0
            # syncfree additionally psums the per-row in-degree counters each
            # superstep (Alg. 2's s.left_sum AND the dependency counters).
            width = B + 1 if self.config.sched == "syncfree" else B
            return (self.bs.nb + 1) * width * itemsize * self.n_supersteps
        if self.config.sched in LEVELSET_SCHEDS:
            # each boundary row is exchanged exactly once, before its level;
            # levels with an empty cut skip the psum entirely (width 0)
            if self.n_boundary_rows == 0:
                return 0
            ex_width = np.asarray(self.buckets, dtype=np.int64)[self.lvl_bucket, 2]
            return int(ex_width.sum()) * B * itemsize
        return self.n_boundary_rows * (B + 1) * itemsize * self.n_supersteps


def _round_up_to(w: np.ndarray, base: int) -> np.ndarray:
    """Round each width up to the next power of ``base`` (0 stays 0)."""
    out = np.ones_like(w)
    while np.any(out < w):
        out = np.where(out < w, out * base, out)
    return np.where(w == 0, 0, out)


def _bucketize_levels(
    ws: np.ndarray, wu: np.ndarray, we: np.ndarray
) -> tuple[tuple, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Choose the per-level padded widths for the three ragged schedules.

    Widths round up a geometric ladder; the ladder coarsens (base 2 -> 4 -> 16)
    until the number of distinct (ws, wu, we) combos fits MAX_BUCKETS, and in
    the worst case degenerates to the single global-max bucket (the old dense
    layout). Returns (buckets, bucket_id, bws, bwu, bwe).
    """
    T = ws.shape[0]
    if T == 0:
        # empty schedule: an all-zero bucket keeps every executor branch a
        # no-op — a nonzero width would make the (never-executed) branch
        # index the 0-row offset table at trace time
        z = np.zeros(0, dtype=np.int64)
        return ((0, 0, 0),), np.zeros(0, np.int32), z, z, z
    for base in (2, 4, 16, 0):
        if base:
            bws, bwu, bwe = (_round_up_to(w, base) for w in (ws, wu, we))
        else:  # fallback: one global bucket per schedule (pad-to-max)
            bws, bwu, bwe = (
                np.where(w == 0, 0, max(1, int(w.max()))) for w in (ws, wu, we)
            )
        combos = np.unique(np.stack([bws, bwu, bwe], axis=1), axis=0)
        if combos.shape[0] <= MAX_BUCKETS:
            break
    key = {tuple(int(v) for v in c): i for i, c in enumerate(combos)}
    bucket_id = np.array(
        [key[(int(bws[t]), int(bwu[t]), int(bwe[t]))] for t in range(T)], np.int32
    )
    buckets = tuple(tuple(int(v) for v in c) for c in combos)
    return buckets, bucket_id, bws.astype(np.int64), bwu.astype(np.int64), bwe.astype(np.int64)


def _tiles_by_device(bs: BlockStructure, part: Partition, D: int) -> list:
    """Global tile ids resident on each device (tiles live on their column's
    owner) — the one definition of the device tile-store ordering, shared by
    :func:`build_plan` and :func:`refresh_plan` so a refresh scatters values
    into exactly the slots the compiled executors index."""
    tile_dev = part.owner[bs.off_cols]
    return [np.nonzero(tile_dev == d)[0] for d in range(D)]


def build_plan(
    a: CSR, n_devices: int, config: SolverConfig = SolverConfig(),
    *, transpose: bool = False, part: Partition | None = None,
    verify: str | None = None,
) -> Plan:
    """``part`` reuses an existing partition computed for the same sparsity
    (e.g. a zero-fill factor shares its matrix's pattern, so one partition
    serves both plans). Not applicable to transpose plans (reversed order).

    ``verify`` opts into the static plan verifier (``repro.verify``) right
    after construction: a level name (``"basic"``/``"contracts"``/
    ``"strict"``) runs :func:`repro.verify.verify_plan` at that level and
    raises :class:`repro.verify.PlanVerificationError` on any finding of
    error grade (or any finding at all for ``"strict"``). ``None`` defers to
    the ``REPRO_VERIFY`` environment variable (``1`` = strict, unset = off).
    """
    with get_tracer().span("sptrsv.schedule", n_devices=n_devices,
                           sched=config.sched, comm=config.comm,
                           transpose=transpose) as span:
        plan = _build_plan(a, n_devices, config, transpose=transpose, part=part)
        span.set(n_levels=plan.n_levels, n_buckets=len(plan.buckets),
                 comm_bytes_per_solve=plan.comm_bytes_per_solve)
    # late import: repro.verify walks plans, so it imports this module
    from repro.verify import env_verify_level, verify_plan

    level = env_verify_level(default=verify) if verify is None else verify
    if level is not None:
        verify_plan(plan, level=level).raise_if_failed()
    return plan


def _build_plan(
    a: CSR, n_devices: int, config: SolverConfig = SolverConfig(),
    *, transpose: bool = False, part: Partition | None = None,
) -> Plan:
    if transpose:
        # Solve a^T x = b with the forward-substitution machinery: reverse row
        # and column order of a^T, which is lower-triangular again; rhs/solution
        # are flipped at the DistributedSolver boundary.
        assert part is None, "partition reuse is not valid across reversal"
        a = reverse_transpose(a)
    bs = build_blocks(a, config.block_size)
    cost_weights = None
    if config.calibrate_cost and (config.partition == "malleable"
                                  or config.sched == "dagpart"):
        # calibrated weights drive malleable placement and/or the dagpart
        # merge pass's narrow-level threshold
        from repro.core.costmodel import calibrate_weights

        cost_weights = calibrate_weights(
            config.block_size, backend=config.kernel_backend
        )
    if part is None:
        part = make_partition(
            bs, n_devices, config.partition, config.tasks_per_device,
            cost_weights=cost_weights, cost_R=config.rhs_hint,
        )
    else:
        assert part.owner.shape[0] == bs.nb, "partition/block-structure mismatch"
    nb, B, D = bs.nb, bs.B, n_devices
    T = bs.n_block_levels

    diag = np.concatenate([bs.diag, np.eye(B, dtype=np.float32)[None]], axis=0)
    owner = np.concatenate([part.owner, [-1]]).astype(np.int32)
    indeg = np.concatenate([bs.block_indeg, [0]]).astype(np.int32)

    # --- per-device tile stores (tiles live on their column's owner) ---
    tile_dev = part.owner[bs.off_cols]
    per_dev_tiles = _tiles_by_device(bs, part, D)
    ML = max((t.shape[0] for t in per_dev_tiles), default=0)
    tiles = np.zeros((D, ML + 1, B, B), dtype=np.float32)
    tile_row = np.full((D, ML + 1), nb, dtype=np.int32)
    tile_col = np.full((D, ML + 1), nb, dtype=np.int32)
    local_tile_id = np.full(bs.n_tiles, -1, dtype=np.int64)  # global tile -> local slot
    for d, ids in enumerate(per_dev_tiles):
        k = ids.shape[0]
        tiles[d, :k] = bs.off_tiles[ids]
        tile_row[d, :k] = bs.off_rows[ids]
        tile_col[d, :k] = bs.off_cols[ids]
        local_tile_id[ids] = np.arange(k)

    # --- compacted levelset schedules (ragged flats + width buckets) ---
    lvl = bs.block_level
    rows_by = [[np.nonzero((part.owner == d) & (lvl == t))[0] for t in range(T)] for d in range(D)]
    col_lvl = lvl[bs.off_cols]
    tiles_by = [
        [np.nonzero((tile_dev == d) & (col_lvl == t))[0] for t in range(T)] for d in range(D)
    ]
    b_rows = np.nonzero(part.boundary)[0]
    per_level_ex = [b_rows[lvl[b_rows] == t] for t in range(T)]
    # dagpart: coarsen the level range into merged supersteps, then hoist each
    # merge group's exchange rows into the group's FIRST level slice — the
    # boundary psum runs once per group, right before the merged grid step.
    # Legal by construction: merge_levels only groups levels whose remote
    # sources all solved in an earlier superstep.
    step_off = None
    if config.sched == "dagpart":
        step_off = merge_levels(
            bs, part, merge_width=config.merge_width,
            merge_cost=config.merge_cost,
            cost_weights=cost_weights, cost_R=config.rhs_hint,
        )
        ex_by_level = [np.zeros(0, dtype=b_rows.dtype) for _ in range(T)]
        for k in range(len(step_off) - 1):
            g, h = int(step_off[k]), int(step_off[k + 1])
            ex_by_level[g] = (np.concatenate(per_level_ex[g:h])
                              if h - g > 1 else per_level_ex[g])
    else:
        ex_by_level = per_level_ex

    # per-level required widths (max over devices for the sharded schedules)
    ws = np.array([max(rows_by[d][t].shape[0] for d in range(D)) for t in range(T)],
                  dtype=np.int64) if T else np.zeros(0, np.int64)
    wu = np.array([max(tiles_by[d][t].shape[0] for d in range(D)) for t in range(T)],
                  dtype=np.int64) if T else np.zeros(0, np.int64)
    we = np.array([e.shape[0] for e in ex_by_level], dtype=np.int64)
    buckets, lvl_bucket, bws, bwu, bwe = _bucketize_levels(ws, wu, we)

    lvl_off = np.zeros((T, 3), dtype=np.int32)
    if T:
        lvl_off[:, 0] = np.concatenate([[0], np.cumsum(bws)[:-1]])
        lvl_off[:, 1] = np.concatenate([[0], np.cumsum(bwu)[:-1]])
        lvl_off[:, 2] = np.concatenate([[0], np.cumsum(bwe)[:-1]])
    S = max(1, int(bws.sum())) if T else 1
    U = max(1, int(bwu.sum())) if T else 1
    E = max(1, int(bwe.sum())) if T else 1
    solve_rows = np.full((D, S), -1, dtype=np.int32)
    upd_tiles = np.full((D, U), ML, dtype=np.int32)
    ex_rows = np.full((E,), nb, dtype=np.int32)
    for t in range(T):
        for d in range(D):
            r = rows_by[d][t]
            solve_rows[d, lvl_off[t, 0]: lvl_off[t, 0] + r.shape[0]] = r
            ids = tiles_by[d][t]
            upd_tiles[d, lvl_off[t, 1]: lvl_off[t, 1] + ids.shape[0]] = local_tile_id[ids]
        e = ex_by_level[t]
        ex_rows[lvl_off[t, 2]: lvl_off[t, 2] + e.shape[0]] = e
    ex_boundary = b_rows.astype(np.int32) if b_rows.size else np.full((1,), nb, dtype=np.int32)

    # --- syncfree plan ---
    per_dev_rows = [np.nonzero(part.owner == d)[0] for d in range(D)]
    MLR = max((r.shape[0] for r in per_dev_rows), default=1) or 1
    local_rows = np.full((D, MLR), nb, dtype=np.int32)
    for d, r in enumerate(per_dev_rows):
        local_rows[d, : r.shape[0]] = r

    return Plan(
        bs=bs, part=part, config=config, n_devices=D, n_levels=T,
        diag=diag, owner=owner, indeg=indeg, ex_rows=ex_rows,
        ex_boundary=ex_boundary, lvl_off=lvl_off, lvl_bucket=lvl_bucket,
        buckets=buckets, solve_rows=solve_rows, upd_tiles=upd_tiles,
        local_rows=local_rows, tile_row=tile_row, tile_col=tile_col, tiles=tiles,
        transpose=transpose,
        frontier_caps=(max(1, int(ws.max())) if T else 1,
                       max(1, int(wu.max())) if T else 1),
        step_off=step_off,
    )


def refresh_plan(plan: Plan, a: CSR) -> Plan:
    """Numeric refresh: a new :class:`Plan` carrying ``a``'s values on
    ``plan``'s exact pattern, partition, and compacted schedules.

    This is the *factorize* stage of the analyse/factorize/solve lifecycle:
    ILU-style refactorization changes tile values but never the sparsity, so
    everything symbolic (blocking, levels, partition, bucketized schedules,
    the compiled executors' trace) is reused and only ``diag``/``tiles`` are
    rebuilt — bit-identically to what a fresh :func:`build_plan` on the same
    pattern would produce. Transpose plans refresh through the same row/column
    reversal they were built with.
    """
    with get_tracer().span("sptrsv.refresh", transpose=plan.transpose,
                           n_devices=plan.n_devices):
        if plan.transpose:
            a = reverse_transpose(a)
        bs = refresh_block_values(plan.bs, a)
        B, D = bs.B, plan.n_devices
        diag = np.concatenate([bs.diag, np.eye(B, dtype=np.float32)[None]], axis=0)
        tiles = np.zeros_like(plan.tiles)
        for d, ids in enumerate(_tiles_by_device(bs, plan.part, D)):
            tiles[d, : ids.shape[0]] = bs.off_tiles[ids]
        return dataclasses.replace(plan, bs=bs, diag=diag, tiles=tiles)


# ---------------------------------------------------------------------------
# compacted levelset superstep (shared by local/distributed executors)
# ---------------------------------------------------------------------------


def _compact_level_body(
    plan: Plan, sr, ut, trow, tcol, tiles, diag, b_pad, ex, split_delta=False
):
    """Return the compacted superstep body shared by all levelset executors.

    One branch is built per occurring width-bucket combo and dispatched with
    ``lax.switch`` on the level's bucket id; each branch slices its level's
    rows/tiles at the bucket width (static sizes, dynamic offsets), so the
    solve/update/exchange work scales with the level's bucket instead of the
    global max. ``ex is None`` disables the zero-copy boundary pull.

    Carry is ``(acc, x)``, or ``(acc, delta, x)`` with ``split_delta`` — then
    tile updates land in ``delta`` (the unified executor's not-yet-exchanged
    contributions; incompatible with ``ex``) while solves read ``acc + delta``:
    ``acc`` carries the psum-folded remote contributions, ``delta`` makes
    local contributions from earlier levels of the *same* merged superstep
    visible (dagpart runs several levels between dense exchanges). For
    unmerged levelset supersteps ``delta`` is exactly ``+0.0`` at solve time,
    so subtracting it is bit-inert.
    """
    assert not (split_delta and ex is not None)
    cfg = plan.config
    nb = plan.bs.nb
    off = jnp.asarray(plan.lvl_off)
    bucket_id = jnp.asarray(plan.lvl_bucket)

    def make_branch(w_s: int, w_u: int, w_e: int):
        def branch(t, carry):
            if split_delta:
                acc, delta, x = carry
            else:
                acc, x = carry
            # named_scope annotations are metadata-only (always present in the
            # traced program) so profiles line up with the host-side spans and
            # toggling tracing can never retrace a compiled executor
            if ex is not None and w_e > 0:
                with jax.named_scope("sptrsv.exchange"):
                    # lazy exactly-once pull: combine partial accumulators for
                    # the boundary rows of THIS level right before solving them
                    rows = jax.lax.dynamic_slice(ex, (off[t, 2],), (w_e,))
                    acc = acc.at[rows].set(jax.lax.psum(acc[rows], AXIS))
            if w_s > 0:
                with jax.named_scope("sptrsv.level_solve"):
                    rows = jax.lax.dynamic_slice(sr, (off[t, 0],), (w_s,))
                    safe = jnp.where(rows < 0, nb, rows)
                    rhs = b_pad[safe] - acc[safe]
                    if split_delta:
                        rhs = rhs - delta[safe]
                    xs = ops.batched_block_trsv(
                        diag[safe], rhs, backend=cfg.kernel_backend
                    )
                    x = x.at[safe].set(
                        jnp.where(ops.bcast_trailing(rows >= 0, xs), xs, x[safe])
                    )
            if w_u > 0:
                with jax.named_scope("sptrsv.tile_update"):
                    tids = jax.lax.dynamic_slice(ut, (off[t, 1],), (w_u,))
                    prods = ops.batched_block_gemv(
                        tiles[tids], x[tcol[tids]], backend=cfg.kernel_backend,
                        group=cfg.gemv_group,
                    )
                    if split_delta:
                        delta = delta.at[trow[tids]].add(prods)
                    else:
                        acc = acc.at[trow[tids]].add(prods)
            return (acc, delta, x) if split_delta else (acc, x)

        return branch

    branches = [make_branch(*b) for b in plan.buckets]
    if len(branches) == 1:
        return lambda t, carry: branches[0](t, carry)
    return lambda t, carry: jax.lax.switch(bucket_id[t], branches, t, carry)


# ---------------------------------------------------------------------------
# fused superstep megakernel executors (kernel_backend="fused")
# ---------------------------------------------------------------------------


def level_widths(plan: Plan) -> np.ndarray:
    """(T, 3) per-level (solve, update, exchange) bucket widths."""
    return np.asarray(plan.buckets, dtype=np.int64)[plan.lvl_bucket]


def step_offsets(plan: Plan) -> np.ndarray:
    """(n_steps + 1,) level offsets of the plan's supersteps. Identity
    (one level per superstep) for levelset/syncfree; the merge pass's
    coarsening for dagpart."""
    if plan.step_off is not None:
        return np.asarray(plan.step_off, dtype=np.int32)
    return np.arange(plan.n_levels + 1, dtype=np.int32)


def step_widths(plan: Plan) -> np.ndarray:
    """(n_steps, 3) per-superstep (solve, update, exchange) schedule widths —
    each superstep's contiguous flat slice sums its levels' bucket widths.
    Identical to :func:`level_widths` for unmerged plans."""
    wid = level_widths(plan)
    so = step_offsets(plan).astype(np.int64)
    cs = np.zeros((plan.n_levels + 1, 3), dtype=np.int64)
    np.cumsum(wid, axis=0, out=cs[1:])
    return cs[so[1:]] - cs[so[:-1]]


def fused_segments(plan: Plan) -> np.ndarray:
    """(n_seg, 2) ``[lo, hi)`` level ranges, one fused launch each.

    Collectives cannot live inside a Pallas kernel, so the fused executor
    splits the schedule exactly before every level whose boundary rows must be
    combined: zerocopy breaks at levels with a non-empty exchange bucket (for
    dagpart those are exactly the merge-group starts, so segment boundaries
    always align to superstep boundaries), unified (dense psum every
    superstep) degenerates to one segment per *superstep* — per level when
    unmerged, per merge group for dagpart — and single-device / empty-cut
    plans fuse the whole solve into one launch.
    """
    T = plan.n_levels
    if T == 0:
        return np.zeros((0, 2), dtype=np.int32)
    cfg = plan.config
    if cfg.comm == "unified" and plan.n_devices > 1 and plan.n_boundary_rows > 0:
        so = step_offsets(plan)
        return np.stack([so[:-1], so[1:]], axis=1).astype(np.int32)
    wid = level_widths(plan)
    starts = [0]
    if cfg.comm == "zerocopy" and plan.n_devices > 1 and plan.n_boundary_rows > 0:
        starts += [t for t in range(1, T) if wid[t, 2] > 0]
    starts = np.unique(np.asarray(starts, dtype=np.int32))
    his = np.concatenate([starts[1:], [T]]).astype(np.int32)
    return np.stack([starts, his], axis=1)


# ---------------------------------------------------------------------------
# streaming HBM tile store (kernel_backend="fused_streamed", or auto-upgrade)
# ---------------------------------------------------------------------------

DEFAULT_STREAM_VMEM_LIMIT = 8 * 2**20  # bytes; ~half a TPU core's VMEM


def stream_vmem_limit() -> int:
    """Resident-store VMEM budget (bytes) above which ``kernel_backend="fused"``
    auto-upgrades to the streaming tile store.

    Resolution order: the ``REPRO_STREAM_VMEM_LIMIT`` env override (an int;
    lower it to force streaming), then the per-platform threshold calibrated
    from the auto-tuner's probe-solve measurements
    (:func:`repro.obs.calibration.calibrated_stream_limit` — when the store
    holds paired fused / fused_streamed timings, the crossover moves with the
    measured streaming overhead), then the fixed 8 MiB default."""
    env = os.environ.get("REPRO_STREAM_VMEM_LIMIT")
    if env is not None:
        return int(env)
    from repro.obs.calibration import calibrated_stream_limit

    lim = calibrated_stream_limit()
    return DEFAULT_STREAM_VMEM_LIMIT if lim is None else lim


def stream_widths(plan: Plan) -> tuple[tuple, tuple]:
    """Static DMA ladders: the distinct per-*superstep* (solve, update)
    schedule widths (:func:`step_widths` — equal to the per-level bucket
    widths for unmerged plans; summed over a merge group for dagpart, whose
    grid steps fetch a whole group's slice in one burst). The streamed kernel
    unrolls one predicated async-copy per ladder entry, so DMA start/wait
    always agree on the transfer size and the bytes moved equal the compacted
    schedule footprint (no pad-to-max bursts)."""
    if plan.n_levels == 0:
        return (0,), (0,)
    wid = step_widths(plan)
    return (tuple(sorted({int(w) for w in wid[:, 0]})),
            tuple(sorted({int(w) for w in wid[:, 1]})))


def streamed_stores(plan: Plan) -> tuple[np.ndarray, np.ndarray]:
    """Schedule-ordered ``(diag_sched, tiles_sched)`` stores for streaming.

    ``diag_sched[d, k]`` is the diagonal tile of ``solve_rows[d, k]`` and
    ``tiles_sched[d, k]`` the tile of slot ``upd_tiles[d, k]`` — i.e. the
    stores permuted into compacted-schedule order, so level ``t``'s slice is
    the contiguous run ``[lvl_off[t], lvl_off[t] + width)`` and the kernel's
    per-level DMA is a single contiguous burst. Pad slots materialize the
    identity diagonal / zero tile, keeping the streamed arithmetic
    bit-identical to the resident kernel's pad handling.
    """
    nb = plan.bs.nb
    safe = np.where(plan.solve_rows < 0, nb, plan.solve_rows)  # (D, S)
    diag_sched = np.ascontiguousarray(plan.diag[safe])
    tiles_sched = np.ascontiguousarray(
        np.stack([plan.tiles[d][plan.upd_tiles[d]]
                  for d in range(plan.n_devices)]))
    return diag_sched, tiles_sched


def fused_vmem_bytes(plan: Plan, R: int = 1, *, streamed: bool = False) -> int:
    """Estimated peak VMEM footprint (bytes) of one fused superstep launch.

    Resident: the whole ``diag`` + per-device ``tiles`` stores ride in VMEM,
    so the footprint grows with the total tile count. Streamed: the stores
    stay in HBM and only two double-buffers sized by the *widest superstep
    slice* are resident (per level when unmerged, per merge group for
    dagpart). Carries (in + out windows) and the rhs are counted in both.
    """
    B = plan.bs.B
    itemsize = 4
    vec = (plan.bs.nb + 1) * B * max(1, R) * itemsize
    n_carry = 3 if (plan.config.comm == "unified" and plan.n_devices > 1
                    and plan.n_boundary_rows > 0) else 2
    vecs = (2 * n_carry + 1) * vec  # carry in + carry out windows + b_pad
    if streamed:
        if plan.n_levels:
            wid = step_widths(plan)
            ws, wu = int(wid[:, 0].max()), int(wid[:, 1].max())
        else:
            ws = wu = 0
        store = 2 * (max(1, ws) + max(1, wu)) * B * B * itemsize
    else:
        store = (plan.diag.shape[0] + plan.tiles.shape[1]) * B * B * itemsize
    return store + vecs


def stream_dma_bytes_per_solve(plan: Plan) -> int:
    """HBM→VMEM bytes the streamed megakernel moves per solve (one device):
    every level's diag + tile slice exactly once, at its bucket width."""
    if plan.n_levels == 0:
        return 0
    wid = level_widths(plan)
    return int(wid[:, 0].sum() + wid[:, 1].sum()) * plan.bs.B * plan.bs.B * 4


def fused_streaming(plan: Plan, R: int | None = None) -> bool:
    """Whether ``plan``'s fused levelset executor uses the streaming store:
    explicitly (``kernel_backend="fused_streamed"``) or automatically, when
    the resident store's estimated footprint exceeds
    :func:`stream_vmem_limit` — so ``"auto"`` sessions and large plans pick
    streaming without user action. Syncfree plans never stream (the frontier
    executor has no resident tile store problem)."""
    if plan.config.sched not in LEVELSET_SCHEDS:
        return False
    backend = ops.executor_backend(plan.config.kernel_backend)
    if backend == "fused_streamed":
        return True
    if backend != "fused":
        return False
    R = plan.config.rhs_hint if R is None else R
    return fused_vmem_bytes(plan, R, streamed=False) > stream_vmem_limit()


def dispatch_stats(plan: Plan) -> dict:
    """Predicted per-solve dispatch counts for the two levelset executors.

    The switch path re-dispatches gather+TRSV and GEMV+scatter per level (plus
    the boundary psum); the fused path is one megakernel launch per exchange
    segment. This is the launch-count model behind the fused-vs-switch bench
    columns — measured times ride next to it, the counts are exact.
    ``streamed``/``fused_vmem_bytes``/``stream_dma_bytes`` report the fused
    executor's memory plan: whether the tile store streams from HBM, the
    estimated VMEM footprint of the selected variant, and the per-solve DMA
    traffic the streaming pays for that residency.

    Scheduling columns: ``supersteps`` is the plan's bulk-synchronous step
    count, ``supersteps_levelset`` the unmerged baseline (the block level
    count — identical unless ``sched="dagpart"`` merged something), and
    ``superstep_reduction`` their ratio. ``schedule_table_bytes`` is the
    compacted-schedule footprint: every host-built table the executors
    index (flats, offsets, buckets, stores' index maps, the step table).
    """
    wid = level_widths(plan)
    cfg = plan.config
    has_ex = (cfg.comm == "zerocopy" and plan.n_devices > 1
              and plan.n_boundary_rows > 0)
    unified = (cfg.comm == "unified" and plan.n_devices > 1
               and plan.n_boundary_rows > 0)
    n_ex = (int((wid[:, 2] > 0).sum()) if has_ex
            else (plan.n_supersteps if unified else 0))
    switch = int(2 * (wid[:, 0] > 0).sum() + 2 * (wid[:, 1] > 0).sum()) + n_ex
    n_seg = int(len(fused_segments(plan)))
    streamed = fused_streaming(plan)
    n_steps = plan.n_supersteps
    return {"switch_dispatches": switch, "fused_launches": n_seg,
            "exchanges": n_ex, "streamed": streamed,
            "fused_vmem_bytes": fused_vmem_bytes(
                plan, plan.config.rhs_hint, streamed=streamed),
            "stream_dma_bytes": stream_dma_bytes_per_solve(plan) if streamed else 0,
            "supersteps": n_steps,
            "supersteps_levelset": plan.n_levels,
            "superstep_reduction": (plan.n_levels / n_steps) if n_steps else 1.0,
            "schedule_table_bytes": schedule_table_bytes(plan)}


def schedule_table_bytes(plan: Plan) -> int:
    """Bytes of the host-built schedule tables the executors index — the
    compacted-schedule footprint that rides to the device as jit arguments
    (and, for the streamed kernel, bounds the scalar-prefetch SMEM traffic).
    Merging supersteps shrinks the exchange flat (one group slice instead of
    many per-level slices) and adds only the tiny step table."""
    arrs = [plan.lvl_off, plan.lvl_bucket, plan.solve_rows, plan.upd_tiles,
            plan.ex_rows, plan.ex_boundary, plan.local_rows,
            plan.tile_row, plan.tile_col]
    if plan.step_off is not None:
        arrs.append(plan.step_off)
    return int(sum(np.asarray(x).nbytes for x in arrs))


def _fused_device_args(plan: Plan, d: int = 0):
    """Device-local schedule arrays for a direct (non-shard_map) fused call."""
    return (
        jnp.asarray(plan.lvl_off), jnp.asarray(level_widths(plan)),
        jnp.asarray(plan.solve_rows[d]), jnp.asarray(plan.upd_tiles[d]),
        jnp.asarray(plan.tile_row[d]), jnp.asarray(plan.tile_col[d]),
        jnp.asarray(plan.diag), jnp.asarray(plan.tiles[d]),
    )


def _fused_levelset_device_fn(plan: Plan):
    """Megakernel levelset executor: one Pallas launch per exchange segment.

    Mirrors the ``lax.switch`` executors' arithmetic exactly — the same
    per-level exchange (packed psum at the level's bucket width, or the
    unified dense delta psum) runs *between* launches, and everything between
    two exchanges fuses into a single scalar-prefetched superstep kernel.

    When :func:`fused_streaming` selects the streaming store, the
    ``diag``/``tiles`` arguments are the *schedule-ordered* per-device stores
    from :func:`streamed_stores` (both sharded) and every launch double-buffers
    its levels' slices from HBM instead of holding the stores in VMEM.
    """
    cfg = plan.config
    nb, T, D = plan.bs.nb, plan.n_levels, plan.n_devices
    # both paths gate on a non-empty cut: with every update device-local the
    # psums would only move zeros, so the whole solve fuses into one launch
    unified = cfg.comm == "unified" and D > 1 and plan.n_boundary_rows > 0
    has_ex = cfg.comm == "zerocopy" and D > 1 and plan.n_boundary_rows > 0
    segs = fused_segments(plan)
    n_seg = max(1, len(segs))
    so = step_offsets(plan)
    # the kernel grids over SUPERSTEPS (one level each for unmerged plans, a
    # whole merge group for dagpart); segment boundaries always align to
    # superstep starts, so each segment maps to a contiguous step range
    step_of = (np.repeat(np.arange(len(so) - 1), np.diff(so))
               if T else np.zeros(0, np.int64))
    if len(segs):
        s_lo = step_of[segs[:, 0]]
        seg_len = step_of[segs[:, 1] - 1] + 1 - s_lo
    else:
        s_lo = seg_len = np.zeros(1, np.int64)
    grid = max(1, int(seg_len.max(initial=0)))
    wid = level_widths(plan)
    interp = ops.interpret_mode()
    streamed = fused_streaming(plan)
    sw, uw = stream_widths(plan) if streamed else ((), ())
    seg_tab = np.stack([s_lo, seg_len], axis=1).astype(np.int32)
    if has_ex and len(segs):
        # per-segment exchange width = the first level's exchange bucket
        ex_w = wid[segs[:, 0], 2]
        ex_ladder = sorted({int(w) for w in ex_w})
        ex_sel = np.array([ex_ladder.index(int(w)) for w in ex_w], np.int32)
        ex_off = plan.lvl_off[segs[:, 0], 2].astype(np.int32)

    def fn(sr, ut, trow, tcol, tiles, owner_mask, diag, ex, b_pad):
        sr, ut = sr[0], ut[0]
        trow, tcol, tiles, owner_mask = trow[0], tcol[0], tiles[0], owner_mask[0]
        if streamed:
            diag = diag[0]  # schedule-ordered stores are per-device (sharded)
        off_a = jnp.asarray(plan.lvl_off)
        wid_a = jnp.asarray(wid)
        seg_a = jnp.asarray(seg_tab)
        stp_a = jnp.asarray(so.astype(np.int32))
        z = jnp.zeros_like(b_pad)

        if has_ex:
            ex_off_a = jnp.asarray(ex_off)
            ex_sel_a = jnp.asarray(ex_sel)

            def make_branch(w):
                def br(s, acc):
                    if w == 0:
                        return acc
                    rows = jax.lax.dynamic_slice(ex, (ex_off_a[s],), (w,))
                    return acc.at[rows].set(jax.lax.psum(acc[rows], AXIS))

                return br

            ex_branches = [make_branch(w) for w in ex_ladder]

        def body(s, carry):
            if unified:
                acc, delta, x = carry
                with jax.named_scope("sptrsv.exchange"):
                    acc = acc + jax.lax.psum(delta, AXIS)
                    delta = jnp.zeros_like(delta)
                with jax.named_scope("sptrsv.superstep"):
                    return superstep_call(
                        seg_a[s], off_a, wid_a, sr, ut, trow, tcol, diag, tiles,
                        b_pad, acc, x, delta, stp=stp_a, grid=grid,
                        split_delta=True, interpret=interp, stream=streamed,
                        solve_widths=sw, upd_widths=uw,
                    )
            acc, x = carry
            if has_ex:
                with jax.named_scope("sptrsv.exchange"):
                    if len(ex_branches) == 1:
                        acc = ex_branches[0](s, acc)
                    else:
                        acc = jax.lax.switch(ex_sel_a[s], ex_branches, s, acc)
            with jax.named_scope("sptrsv.superstep"):
                return superstep_call(
                    seg_a[s], off_a, wid_a, sr, ut, trow, tcol, diag, tiles,
                    b_pad, acc, x, stp=stp_a, grid=grid, interpret=interp,
                    stream=streamed, solve_widths=sw, upd_widths=uw,
                )

        init = (z, z, z) if unified else (z, z)
        carry = jax.lax.fori_loop(0, n_seg, body, init)
        x = carry[-1]
        with jax.named_scope("sptrsv.gather"):
            xg = x * ops.bcast_trailing(owner_mask, x)
            if D > 1:
                xg = jax.lax.psum(xg, AXIS)
        return xg[:nb]

    return fn


# ---------------------------------------------------------------------------
# single-device levelset executor (the "1-GPU" baseline and structural oracle)
# ---------------------------------------------------------------------------


def solve_local(plan: Plan, b_blocks: jax.Array) -> jax.Array:
    """Level-scheduled solve on one device. b_blocks: (nb, B) -> x (nb, B)."""
    nb = plan.bs.nb
    b_pad = jnp.concatenate(
        [b_blocks, jnp.zeros((1,) + b_blocks.shape[1:], b_blocks.dtype)]
    )
    if ops.is_fused(plan.config.kernel_backend):
        # the whole solve is one megakernel launch (no exchanges on 1 device)
        off, wid, sr, ut, trow, tcol, diag, tiles = _fused_device_args(plan, 0)
        streamed = fused_streaming(plan)
        sw, uw = ((), ())
        if streamed:
            diag_s, tiles_s = streamed_stores(plan)
            diag, tiles = jnp.asarray(diag_s[0]), jnp.asarray(tiles_s[0])
            sw, uw = stream_widths(plan)
        acc0 = jnp.zeros_like(b_pad)
        seg = jnp.array([0, plan.n_supersteps], jnp.int32)
        stp = jnp.asarray(step_offsets(plan))
        _, x = superstep_call(
            seg, off, wid, sr, ut, trow, tcol, diag, tiles, b_pad, acc0, acc0,
            stp=stp, grid=max(1, plan.n_supersteps),
            interpret=ops.interpret_mode(),
            stream=streamed, solve_widths=sw, upd_widths=uw,
        )
        return x[:nb]
    diag = jnp.asarray(plan.diag)
    sr = jnp.asarray(plan.solve_rows[0])
    ut = jnp.asarray(plan.upd_tiles[0])
    trow = jnp.asarray(plan.tile_row[0])
    tcol = jnp.asarray(plan.tile_col[0])
    tiles = jnp.asarray(plan.tiles[0])
    body = _compact_level_body(plan, sr, ut, trow, tcol, tiles, diag, b_pad, ex=None)
    acc0 = jnp.zeros_like(b_pad)
    _, x = jax.lax.fori_loop(0, plan.n_levels, body, (acc0, acc0))
    return x[:nb]


# ---------------------------------------------------------------------------
# distributed executors (shard_map over AXIS)
# ---------------------------------------------------------------------------


def _levelset_device_fn(plan: Plan):
    cfg = plan.config
    nb, T = plan.bs.nb, plan.n_levels
    # pad-traffic gate: only exchange when a psum can carry real data — the
    # partition actually cut boundary rows AND there is a peer to combine with
    has_ex = (
        cfg.comm == "zerocopy" and plan.n_devices > 1 and plan.n_boundary_rows > 0
    )

    def fn(sr, ut, trow, tcol, tiles, owner_mask, diag, ex, b_pad):
        # leading device dim of sharded operands is 1 inside shard_map
        sr, ut = sr[0], ut[0]
        trow, tcol, tiles, owner_mask = trow[0], tcol[0], tiles[0], owner_mask[0]
        body = _compact_level_body(
            plan, sr, ut, trow, tcol, tiles, diag, b_pad,
            ex=ex if has_ex else None,
        )
        acc0 = jnp.zeros_like(b_pad)
        _, x = jax.lax.fori_loop(0, T, body, (acc0, acc0))
        with jax.named_scope("sptrsv.gather"):
            xg = x * ops.bcast_trailing(owner_mask, x)
            if plan.n_devices > 1:
                xg = jax.lax.psum(xg, AXIS)
        return xg[:nb]

    return fn


def _levelset_unified_device_fn(plan: Plan):
    """Unified-memory analogue: delta accumulators + full-array psum per
    *superstep* — once per level when unmerged, once per merge group for
    dagpart (the levels inside a group see each other's local contributions
    through ``delta``, which solves read alongside ``acc``)."""
    nb = plan.bs.nb
    so = step_offsets(plan)
    n_steps = plan.n_supersteps

    def fn(sr, ut, trow, tcol, tiles, owner_mask, diag, ex, b_pad):
        del ex  # unified ignores the packed exchange schedule
        sr, ut = sr[0], ut[0]
        trow, tcol, tiles, owner_mask = trow[0], tcol[0], tiles[0], owner_mask[0]
        step = _compact_level_body(
            plan, sr, ut, trow, tcol, tiles, diag, b_pad, ex=None, split_delta=True
        )
        stp = jnp.asarray(so.astype(np.int32))

        def body(s, carry):
            acc_red, delta, x = carry
            # dense exchange of everything accumulated since the last
            # superstep — the page-bouncing s.left_sum traffic of Alg. 2.
            with jax.named_scope("sptrsv.exchange"):
                acc_red = acc_red + jax.lax.psum(delta, AXIS)
                delta = jnp.zeros_like(delta)
            return jax.lax.fori_loop(stp[s], stp[s + 1], step,
                                     (acc_red, delta, x))

        z = jnp.zeros_like(b_pad)
        _, _, x = jax.lax.fori_loop(0, n_steps, body, (z, z, z))
        with jax.named_scope("sptrsv.gather"):
            return jax.lax.psum(x * ops.bcast_trailing(owner_mask, x), AXIS)[:nb]

    return fn


def _frontier_ladder(cap: int) -> tuple:
    """Geometric width ladder ``1, b, b², ..., cap`` for the runtime frontier;
    the base coarsens (2 -> 4 -> 16) until the ladder fits MAX_BUCKETS."""
    cap = max(1, int(cap))
    for base in (2, 4, 16):
        lad = sorted({cap} | {base ** k for k in range(64) if base ** k < cap})
        if len(lad) <= MAX_BUCKETS:
            return tuple(int(w) for w in lad)
    return (cap,)


def _syncfree_device_fn(plan: Plan, frontier: bool = False):
    """Runtime-frontier solver: no level analysis, in-degree counters drive it.

    ``frontier=False`` is the paper-faithful dense scan: every sweep solves a
    masked TRSV over *all* local rows and a masked GEMV over *all* local
    tiles. ``frontier=True`` (the ``fused`` backend) compacts the ready set
    each sweep and dispatches one ``lax.switch`` branch at the smallest
    bucket width covering it — the same width-ladder trick as the compacted
    levelset schedules, keyed on the *runtime* frontier size, so per-sweep
    work scales with the frontier, not with the device's whole row set. The
    ladder is capped by ``plan.frontier_caps`` (a bulk-synchronous sweep
    solves exactly one block level, so the frontier never exceeds the widest
    per-device level).
    """
    cfg = plan.config
    nb, B = plan.bs.nb, plan.bs.B
    zerocopy = cfg.comm == "zerocopy"
    multi = plan.n_devices > 1
    # with no boundary rows every tile's contribution is device-local, so any
    # exchange (packed psum of the [nb] sentinel, or unified's dense
    # all-reduce of all-zero deltas) would move no information — skip it and
    # the delta/dcnt split entirely
    has_ex = zerocopy and multi and plan.n_boundary_rows > 0
    needs_ex = multi and plan.n_boundary_rows > 0
    MLR = plan.local_rows.shape[1]
    MLT = plan.tiles.shape[1]  # ML + 1 (pad slot holds the zero tile, dest nb)
    lad_s = _frontier_ladder(min(plan.frontier_caps[0], MLR))
    lad_u = _frontier_ladder(min(plan.frontier_caps[1], MLT))

    def fn(lr, trow, tcol, tiles, owner_mask, diag, indeg, exb, b_pad):
        lr = lr[0]
        trow, tcol, tiles, owner_mask = trow[0], tcol[0], tiles[0], owner_mask[0]
        me = jax.lax.axis_index(AXIS) if multi else 0
        ldiag = diag[lr]
        lb = b_pad[lr]
        lown = owner_mask[lr] > 0  # valid (non-pad) local rows
        dest_mine = owner_mask[trow] > 0  # tile dest owned by this device
        iota_l = jnp.arange(MLR, dtype=jnp.int32)
        iota_t = jnp.arange(MLT, dtype=jnp.int32)
        lad_s_a = jnp.asarray(lad_s, jnp.int32)
        lad_u_a = jnp.asarray(lad_u, jnp.int32)

        def solve_branch(w):
            def br(order, acc_red, x):
                idx = jax.lax.dynamic_slice(order, (0,), (w,))
                valid = idx < MLR
                rows = jnp.where(valid, lr[jnp.where(valid, idx, 0)], nb)
                xs = ops.batched_block_trsv(
                    diag[rows], b_pad[rows] - acc_red[rows],
                    backend=cfg.kernel_backend,
                )
                return x.at[rows].set(
                    jnp.where(ops.bcast_trailing(valid, xs), xs, x[rows])
                )

            return br

        def upd_branch(w):
            def br(torder, x, acc_red, delta, cnt_red, dcnt):
                tid = jax.lax.dynamic_slice(torder, (0,), (w,))
                valid = tid < MLT
                tid = jnp.where(valid, tid, MLT - 1)  # pad: zero tile, dest nb
                rd = trow[tid]
                dmine = dest_mine[tid]
                prods = ops.batched_block_gemv(
                    tiles[tid], x[tcol[tid]], backend=cfg.kernel_backend,
                    group=cfg.gemv_group,
                )
                pm = jnp.where(ops.bcast_trailing(valid, prods), prods, 0.0)
                cm = valid.astype(jnp.int32)
                if needs_ex:
                    dm = ops.bcast_trailing(dmine, pm)
                    acc_red = acc_red.at[rd].add(jnp.where(dm, pm, 0.0))
                    cnt_red = cnt_red.at[rd].add(jnp.where(dmine, cm, 0))
                    delta = delta.at[rd].add(jnp.where(dm, 0.0, pm))
                    dcnt = dcnt.at[rd].add(jnp.where(dmine, 0, cm))
                else:
                    acc_red = acc_red.at[rd].add(pm)
                    cnt_red = cnt_red.at[rd].add(cm)
                return acc_red, delta, cnt_red, dcnt

            return br

        solve_branches = [solve_branch(w) for w in lad_s]
        upd_branches = [upd_branch(w) for w in lad_u]

        def cond(state):
            return jnp.logical_not(state["done"])

        def body(state):
            acc_red, delta, cnt_red, dcnt, solved, x = (
                state["acc_red"], state["delta"], state["cnt_red"],
                state["dcnt"], state["solved"], state["x"],
            )
            # 1. frontier: owned, unsolved, all dependencies counted in
            ready = jnp.logical_and(
                jnp.logical_and(lown, jnp.logical_not(solved[lr])),
                cnt_red[lr] == indeg[lr],
            )
            if frontier:
                # 2. compact the frontier, solve at its bucket width
                with jax.named_scope("sptrsv.level_solve"):
                    order = jnp.sort(jnp.where(ready, iota_l, MLR).astype(jnp.int32))
                    sel = jnp.sum((lad_s_a < jnp.sum(ready)).astype(jnp.int32))
                    if len(solve_branches) == 1:
                        x = solve_branches[0](order, acc_red, x)
                    else:
                        x = jax.lax.switch(sel, solve_branches, order, acc_red, x)
                solved = solved.at[lr].set(jnp.logical_or(solved[lr], ready))
                # 3. compact the tiles sourced at this frontier, update at width
                just = jnp.zeros((nb + 1,), jnp.bool_).at[lr].set(ready)
                tmask = just[tcol]
                torder = jnp.sort(jnp.where(tmask, iota_t, MLT).astype(jnp.int32))
                usel = jnp.sum((lad_u_a < jnp.sum(tmask)).astype(jnp.int32))
                if len(upd_branches) == 1:
                    acc_red, delta, cnt_red, dcnt = upd_branches[0](
                        torder, x, acc_red, delta, cnt_red, dcnt)
                else:
                    acc_red, delta, cnt_red, dcnt = jax.lax.switch(
                        usel, upd_branches, torder, x, acc_red, delta,
                        cnt_red, dcnt)
            else:
                # 2. solve the frontier (masked dense over local rows)
                with jax.named_scope("sptrsv.level_solve"):
                    xs = ops.batched_block_trsv(
                        ldiag, lb - acc_red[lr], backend=cfg.kernel_backend
                    )
                    x = x.at[lr].set(
                        jnp.where(ops.bcast_trailing(ready, xs), xs, x[lr]))
                solved = solved.at[lr].set(jnp.logical_or(solved[lr], ready))
                # 3. updates from tiles whose source column solved THIS superstep
                just = jnp.zeros((nb + 1,), jnp.bool_).at[lr].set(ready)
                tmask = just[tcol]
                prods = ops.batched_block_gemv(
                    tiles, x[tcol], backend=cfg.kernel_backend, group=cfg.gemv_group
                )
                pm = jnp.where(ops.bcast_trailing(tmask, prods), prods, 0.0)
                cm = tmask.astype(jnp.int32)
                if needs_ex:
                    dm = ops.bcast_trailing(dest_mine, pm)
                    acc_red = acc_red.at[trow].add(jnp.where(dm, pm, 0.0))
                    cnt_red = cnt_red.at[trow].add(jnp.where(dest_mine, cm, 0))
                    delta = delta.at[trow].add(jnp.where(dm, 0.0, pm))
                    dcnt = dcnt.at[trow].add(jnp.where(dest_mine, 0, cm))
                else:
                    # single device, or zerocopy with an empty cut: every
                    # tile's destination is local, no exchange needed
                    acc_red = acc_red.at[trow].add(pm)
                    cnt_red = cnt_red.at[trow].add(cm)
            # 4. exchange remote contributions
            if needs_ex:
                with jax.named_scope("sptrsv.exchange"):
                    if has_ex:  # packed boundary rows only
                        red = jax.lax.psum(delta[exb], AXIS)
                        redc = jax.lax.psum(dcnt[exb], AXIS)
                        acc_red = acc_red.at[exb].add(red)
                        cnt_red = cnt_red.at[exb].add(redc)
                        delta = delta.at[exb].set(0.0)
                        dcnt = dcnt.at[exb].set(0)
                    else:  # unified: dense all-reduce of values and counters
                        acc_red = acc_red + jax.lax.psum(delta, AXIS)
                        cnt_red = cnt_red + jax.lax.psum(dcnt, AXIS)
                        delta = jnp.zeros_like(delta)
                        dcnt = jnp.zeros_like(dcnt)
            # 5. global termination check
            remaining = jnp.sum(jnp.logical_and(lown, jnp.logical_not(solved[lr])))
            if multi:
                remaining = jax.lax.psum(remaining, AXIS)
            return dict(
                acc_red=acc_red, delta=delta, cnt_red=cnt_red, dcnt=dcnt,
                solved=solved, x=x, done=remaining == 0,
            )

        zf = jnp.zeros_like(b_pad)
        zi = jnp.zeros((nb + 1,), jnp.int32)
        state = dict(
            acc_red=zf, delta=zf, cnt_red=zi, dcnt=zi,
            solved=jnp.zeros((nb + 1,), jnp.bool_), x=zf,
            done=jnp.asarray(False),
        )
        state = jax.lax.while_loop(cond, body, state)
        with jax.named_scope("sptrsv.gather"):
            xg = state["x"] * ops.bcast_trailing(owner_mask, state["x"])
            if multi:
                xg = jax.lax.psum(xg, AXIS)
        return xg[:nb]

    return fn


class DistributedSolver:
    """Compiled multi-device SpTRSV for one (matrix, partition, mesh).

    One instance is compiled once and invoked many times — the amortized
    regime of preconditioned Krylov loops. ``n_solves`` counts invocations
    (each multi-RHS panel counts once: one compiled solve serves R systems).
    """

    def __init__(self, plan: Plan, mesh: jax.sharding.Mesh):
        assert mesh.devices.size == plan.n_devices, (mesh.devices.size, plan.n_devices)
        self.plan = plan
        self.mesh = mesh
        self.n_solves = 0
        nb = plan.bs.nb
        D = plan.n_devices
        owner_mask = np.zeros((D, nb + 1), np.float32)
        for d in range(D):
            owner_mask[d, :nb] = (plan.part.owner == d).astype(np.float32)
        self._owner_mask = owner_mask

        sharded = P(AXIS)
        repl = P()
        backend = ops.executor_backend(plan.config.kernel_backend)
        self._streamed = fused_streaming(plan)
        if plan.config.sched in LEVELSET_SCHEDS:
            if backend in ops.FUSED_BACKENDS:
                fn = _fused_levelset_device_fn(plan)
            else:
                # unified with an empty cut degrades to the exchange-free
                # executor: the dense per-level psums would only move zeros
                fn = (
                    _levelset_device_fn(plan)
                    if plan.config.comm == "zerocopy" or D == 1
                    or plan.n_boundary_rows == 0
                    else _levelset_unified_device_fn(plan)
                )
            # streaming swaps the replicated diag for the per-device
            # schedule-ordered store, which is sharded like the tiles
            diag_spec = sharded if self._streamed else repl
            in_specs = (sharded,) * 6 + (diag_spec, repl, repl)
        else:
            fn = _syncfree_device_fn(plan, frontier=backend in ops.FUSED_BACKENDS)
            in_specs = (sharded,) * 5 + (repl, repl, repl, repl)
        self._args = self._plan_args(plan)
        mapped = compat.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=P(),
        )
        self._jitted = jax.jit(mapped)

    def _plan_args(self, plan: Plan) -> tuple:
        if plan.config.sched in LEVELSET_SCHEDS:
            diag, tiles = plan.diag, plan.tiles
            if self._streamed:
                # schedule-ordered HBM stores; recomputed here on every
                # refresh so re-armed values reach the streamed kernel too
                diag, tiles = streamed_stores(plan)
            return (plan.solve_rows, plan.upd_tiles, plan.tile_row,
                    plan.tile_col, tiles, self._owner_mask, diag,
                    plan.ex_rows)
        return (plan.local_rows, plan.tile_row, plan.tile_col,
                plan.tiles, self._owner_mask, plan.diag, plan.indeg,
                plan.ex_boundary)

    def refresh(self, plan: Plan) -> None:
        """Swap in a numerically refreshed plan (:func:`refresh_plan`) without
        recompiling: the executor trace bakes in the *schedules*, while tile
        and diagonal values ride in as jit arguments — same shapes, same
        compiled program, zero retrace."""
        old = self.plan
        # the compiled trace bakes the old schedule in as constants, so a
        # structurally different plan would silently pair new values with the
        # wrong schedule — reject it loudly (never an assert: -O must not
        # disable this)
        if not (plan.config == old.config and plan.n_devices == old.n_devices
                and plan.transpose == old.transpose
                and np.array_equal(plan.solve_rows, old.solve_rows)
                and np.array_equal(plan.lvl_off, old.lvl_off)
                and np.array_equal(step_offsets(plan), step_offsets(old))
                and np.array_equal(plan.local_rows, old.local_rows)
                and np.array_equal(plan.tile_row, old.tile_row)):
            raise ValueError(
                "refresh requires an identical symbolic schedule (same "
                "pattern, config, and device count as the compiled plan)"
            )
        self.plan = plan
        self._args = self._plan_args(plan)

    def solve_blocks(self, b_blocks: jax.Array) -> jax.Array:
        """b_blocks: (nb, B) or a multi-RHS panel (nb, B, R) -> same shape."""
        self.n_solves += 1
        b_pad = jnp.concatenate(
            [b_blocks, jnp.zeros((1,) + b_blocks.shape[1:], b_blocks.dtype)]
        )
        return self._jitted(*self._args, b_pad)

    def solve(self, b: np.ndarray) -> np.ndarray:
        """b: (n,) or (n, R) RHS panel. Transpose plans flip row order at this
        boundary (the plan was built on ``reverse_transpose(a)``)."""
        from repro.core.blocking import pad_rhs, unpad_x

        b = np.asarray(b, np.float32)
        if self.plan.transpose:
            b = b[::-1]
        b_blocks = jnp.asarray(pad_rhs(b, self.plan.bs))
        x = unpad_x(np.asarray(self.solve_blocks(b_blocks)), self.plan.bs)
        return x[::-1].copy() if self.plan.transpose else x


def sptrsv(
    a: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig = SolverConfig(), transpose: bool = False,
) -> np.ndarray:
    """Deprecated one-shot API: analyse, plan, solve Lx=b (or L^T x=b).

    Kept as a thin shim over :class:`repro.api.SpTRSVContext` — it re-runs the
    full analysis on every call, which is exactly the cost the session API
    amortizes. New code should hold a context and call
    ``ctx.solve(ctx.analyse(a), b)``.
    """
    warnings.warn(
        "repro.core.sptrsv is deprecated: use repro.api.SpTRSVContext "
        "(analyse once, factorize/solve many)", DeprecationWarning, stacklevel=2,
    )
    from repro.api import SpTRSVContext

    ctx = SpTRSVContext(mesh=mesh, options=config)
    return ctx.solve(ctx.analyse(a), b, transpose=transpose)
