"""Distributed SpTRSV — the paper's contribution, TPU-native (DESIGN.md §5).

Execution model
---------------
Block-rows are distributed by a :class:`~repro.core.partition.Partition`
(each device owns block-row *and* block-column ``r`` — the paper's layout
where components x, columns of L and rhs b are co-partitioned). Tiles live on
the owner of their *column*, so an update ``acc[r] += L[r,c] @ x[c]`` is always
computed where ``x[c]`` was produced: the **only** communication is combining
per-device partial accumulators — the paper's read-only model, where each PE
accumulates into its own symmetric-heap array and the owner of a row pulls and
reduces partials right before solving.

Communication modes (paper Fig. 7 scenarios):
* ``unified``  — all-reduce the *full* n-sized accumulator delta every
  superstep (the Unified-Memory analogue: dense, cut-oblivious traffic).
* ``zerocopy`` — exchange only *packed boundary rows*; in ``levelset``
  scheduling each row is exchanged exactly once, lazily, right before its
  level (the NVSHMEM get+warp-reduce analogue: psum of the packed buffer).

Scheduling modes:
* ``levelset`` — host-precomputed block wavefronts (Naumov-style baseline).
* ``syncfree`` — no level analysis; runtime in-degree counters discover the
  frontier each superstep (the paper's synchronization-free algorithm,
  bulk-synchronous TPU adaptation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.blocking import BlockStructure, build_blocks
from repro.core.partition import Partition, make_partition
from repro.kernels import ops
from repro.sparse.matrix import CSR, reverse_transpose

AXIS = "x"  # device axis name used by the solver


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    block_size: int = 32
    comm: str = "zerocopy"  # "zerocopy" | "unified"
    sched: str = "levelset"  # "levelset" | "syncfree"
    partition: str = "taskpool"  # "taskpool" | "contiguous"
    tasks_per_device: int = 8
    kernel_backend: str | None = None  # None -> ops default ("reference" on CPU)
    gemv_group: int = 0


@dataclasses.dataclass(frozen=True)
class Plan:
    """Host-built execution plan: everything static for a (matrix, partition)."""

    bs: BlockStructure
    part: Partition
    config: SolverConfig
    n_devices: int
    n_levels: int
    # replicated
    diag: np.ndarray  # (nb+1, B, B) identity at pad slot
    owner: np.ndarray  # (nb+1,) int32, -1 at pad
    indeg: np.ndarray  # (nb+1,) int32 tile in-degree per block row
    ex_levels: np.ndarray  # (T, ME) rows exchanged before level t (levelset/zerocopy)
    ex_boundary: np.ndarray  # (MEB,) static boundary row list (syncfree/zerocopy)
    # sharded by leading device axis
    solve_rows: np.ndarray  # (D, T, MS) owned rows per level, pad -1 (levelset)
    upd_tiles: np.ndarray  # (D, T, MU) local tile ids per level, pad ML (levelset)
    local_rows: np.ndarray  # (D, MLR) owned rows, pad nb (syncfree)
    tile_row: np.ndarray  # (D, ML+1) dest block-row per local tile, pad nb
    tile_col: np.ndarray  # (D, ML+1) src block-col per local tile, pad nb
    tiles: np.ndarray  # (D, ML+1, B, B) zero tile at pad slot
    transpose: bool = False  # plan solves a^T x = b (built on reverse_transpose(a))

    @property
    def n_supersteps(self) -> int:
        """Bulk-synchronous supersteps per solve. Levelset executes one
        superstep per block level; syncfree's runtime frontier discovery also
        converges level-by-level (each superstep solves exactly the rows whose
        in-degree count completed, i.e. the next block level)."""
        return self.n_levels

    @property
    def comm_bytes_per_solve(self) -> int:
        """Predicted collective payload bytes for one solve (one device's share)."""
        B = self.bs.B
        itemsize = 4
        if self.config.comm == "unified":
            # syncfree additionally psums the per-row in-degree counters each
            # superstep (Alg. 2's s.left_sum AND the dependency counters).
            width = B if self.config.sched == "levelset" else B + 1
            return (self.bs.nb + 1) * width * itemsize * self.n_supersteps
        if self.config.sched == "levelset":
            return int(self.ex_levels.size) * B * itemsize
        return int(self.ex_boundary.size) * (B + 1) * itemsize * self.n_supersteps


def build_plan(
    a: CSR, n_devices: int, config: SolverConfig = SolverConfig(),
    *, transpose: bool = False, part: Partition | None = None,
) -> Plan:
    """``part`` reuses an existing partition computed for the same sparsity
    (e.g. a zero-fill factor shares its matrix's pattern, so one partition
    serves both plans). Not applicable to transpose plans (reversed order)."""
    if transpose:
        # Solve a^T x = b with the forward-substitution machinery: reverse row
        # and column order of a^T, which is lower-triangular again; rhs/solution
        # are flipped at the DistributedSolver boundary.
        assert part is None, "partition reuse is not valid across reversal"
        a = reverse_transpose(a)
    bs = build_blocks(a, config.block_size)
    if part is None:
        part = make_partition(bs, n_devices, config.partition, config.tasks_per_device)
    else:
        assert part.owner.shape[0] == bs.nb, "partition/block-structure mismatch"
    nb, B, D = bs.nb, bs.B, n_devices
    T = bs.n_block_levels

    diag = np.concatenate([bs.diag, np.eye(B, dtype=np.float32)[None]], axis=0)
    owner = np.concatenate([part.owner, [-1]]).astype(np.int32)
    indeg = np.concatenate([bs.block_indeg, [0]]).astype(np.int32)

    # --- per-device tile stores (tiles live on their column's owner) ---
    tile_dev = part.owner[bs.off_cols]
    per_dev_tiles = [np.nonzero(tile_dev == d)[0] for d in range(D)]
    ML = max((t.shape[0] for t in per_dev_tiles), default=0)
    tiles = np.zeros((D, ML + 1, B, B), dtype=np.float32)
    tile_row = np.full((D, ML + 1), nb, dtype=np.int32)
    tile_col = np.full((D, ML + 1), nb, dtype=np.int32)
    local_tile_id = np.full(bs.n_tiles, -1, dtype=np.int64)  # global tile -> local slot
    for d, ids in enumerate(per_dev_tiles):
        k = ids.shape[0]
        tiles[d, :k] = bs.off_tiles[ids]
        tile_row[d, :k] = bs.off_rows[ids]
        tile_col[d, :k] = bs.off_cols[ids]
        local_tile_id[ids] = np.arange(k)

    # --- levelset plan ---
    lvl = bs.block_level
    rows_by = [[np.nonzero((part.owner == d) & (lvl == t))[0] for t in range(T)] for d in range(D)]
    MS = max((r.shape[0] for dev in rows_by for r in dev), default=1) or 1
    solve_rows = np.full((D, T, MS), -1, dtype=np.int32)
    for d in range(D):
        for t in range(T):
            r = rows_by[d][t]
            solve_rows[d, t, : r.shape[0]] = r

    col_lvl = lvl[bs.off_cols]
    tiles_by = [
        [np.nonzero((tile_dev == d) & (col_lvl == t))[0] for t in range(T)] for d in range(D)
    ]
    MU = max((t.shape[0] for dev in tiles_by for t in dev), default=1) or 1
    upd_tiles = np.full((D, T, MU), ML, dtype=np.int32)
    for d in range(D):
        for t in range(T):
            ids = tiles_by[d][t]
            upd_tiles[d, t, : ids.shape[0]] = local_tile_id[ids]

    # --- exchange lists ---
    b_rows = np.nonzero(part.boundary)[0]
    ex_by_level = [b_rows[lvl[b_rows] == t] for t in range(T)]
    ME = max((e.shape[0] for e in ex_by_level), default=1) or 1
    ex_levels = np.full((T, ME), nb, dtype=np.int32)
    for t in range(T):
        e = ex_by_level[t]
        ex_levels[t, : e.shape[0]] = e
    ex_boundary = b_rows.astype(np.int32) if b_rows.size else np.full((1,), nb, dtype=np.int32)

    # --- syncfree plan ---
    per_dev_rows = [np.nonzero(part.owner == d)[0] for d in range(D)]
    MLR = max((r.shape[0] for r in per_dev_rows), default=1) or 1
    local_rows = np.full((D, MLR), nb, dtype=np.int32)
    for d, r in enumerate(per_dev_rows):
        local_rows[d, : r.shape[0]] = r

    return Plan(
        bs=bs, part=part, config=config, n_devices=D, n_levels=T,
        diag=diag, owner=owner, indeg=indeg, ex_levels=ex_levels,
        ex_boundary=ex_boundary, solve_rows=solve_rows, upd_tiles=upd_tiles,
        local_rows=local_rows, tile_row=tile_row, tile_col=tile_col, tiles=tiles,
        transpose=transpose,
    )


# ---------------------------------------------------------------------------
# single-device levelset executor (the "1-GPU" baseline and structural oracle)
# ---------------------------------------------------------------------------


def solve_local(plan: Plan, b_blocks: jax.Array) -> jax.Array:
    """Level-scheduled solve on one device. b_blocks: (nb, B) -> x (nb, B)."""
    cfg = plan.config
    nb, B = plan.bs.nb, plan.bs.B
    diag = jnp.asarray(plan.diag)
    sr = jnp.asarray(plan.solve_rows.reshape(-1, plan.solve_rows.shape[-1]))  # D=1
    ut = jnp.asarray(plan.upd_tiles.reshape(-1, plan.upd_tiles.shape[-1]))
    trow = jnp.asarray(plan.tile_row[0])
    tcol = jnp.asarray(plan.tile_col[0])
    tiles = jnp.asarray(plan.tiles[0])
    b_pad = jnp.concatenate(
        [b_blocks, jnp.zeros((1,) + b_blocks.shape[1:], b_blocks.dtype)]
    )

    def body(t, carry):
        acc, x = carry
        rows = jax.lax.dynamic_index_in_dim(sr, t, 0, keepdims=False)
        safe = jnp.where(rows < 0, nb, rows)
        xs = ops.batched_block_trsv(
            diag[safe], b_pad[safe] - acc[safe], backend=cfg.kernel_backend
        )
        x = x.at[safe].set(jnp.where(ops.bcast_trailing(rows >= 0, xs), xs, x[safe]))
        tids = jax.lax.dynamic_index_in_dim(ut, t, 0, keepdims=False)
        prods = ops.batched_block_gemv(
            tiles[tids], x[tcol[tids]], backend=cfg.kernel_backend, group=cfg.gemv_group
        )
        acc = acc.at[trow[tids]].add(prods)
        return acc, x

    acc0 = jnp.zeros_like(b_pad)
    _, x = jax.lax.fori_loop(0, plan.n_levels, body, (acc0, acc0))
    return x[:nb]


# ---------------------------------------------------------------------------
# distributed executors (shard_map over AXIS)
# ---------------------------------------------------------------------------


def _levelset_device_fn(plan: Plan):
    cfg = plan.config
    nb, B, T = plan.bs.nb, plan.bs.B, plan.n_levels
    zerocopy = cfg.comm == "zerocopy"
    has_ex = plan.ex_levels.shape[1] > 0 and plan.n_devices > 1

    def fn(sr, ut, trow, tcol, tiles, owner_mask, diag, ex, b_pad):
        # leading device dim of sharded operands is 1 inside shard_map
        sr, ut = sr[0], ut[0]
        trow, tcol, tiles, owner_mask = trow[0], tcol[0], tiles[0], owner_mask[0]

        def body(t, carry):
            acc, x = carry
            if zerocopy and has_ex:
                # lazy exactly-once pull: combine partial accumulators for the
                # boundary rows of THIS level right before solving them
                rows = jax.lax.dynamic_index_in_dim(ex, t, 0, keepdims=False)
                red = jax.lax.psum(acc[rows], AXIS)
                acc = acc.at[rows].set(red)
            rows = jax.lax.dynamic_index_in_dim(sr, t, 0, keepdims=False)
            safe = jnp.where(rows < 0, nb, rows)
            xs = ops.batched_block_trsv(
                diag[safe], b_pad[safe] - acc[safe], backend=cfg.kernel_backend
            )
            x = x.at[safe].set(jnp.where(ops.bcast_trailing(rows >= 0, xs), xs, x[safe]))
            tids = jax.lax.dynamic_index_in_dim(ut, t, 0, keepdims=False)
            prods = ops.batched_block_gemv(
                tiles[tids], x[tcol[tids]], backend=cfg.kernel_backend, group=cfg.gemv_group
            )
            acc = acc.at[trow[tids]].add(prods)
            return acc, x

        acc0 = jnp.zeros_like(b_pad)
        _, x = jax.lax.fori_loop(0, T, body, (acc0, acc0))
        xg = x * ops.bcast_trailing(owner_mask, x)
        if plan.n_devices > 1:
            xg = jax.lax.psum(xg, AXIS)
        return xg[:nb]

    return fn


def _levelset_unified_device_fn(plan: Plan):
    """Unified-memory analogue: delta accumulators + full-array psum per level."""
    cfg = plan.config
    nb, B, T = plan.bs.nb, plan.bs.B, plan.n_levels

    def fn(sr, ut, trow, tcol, tiles, owner_mask, diag, ex, b_pad):
        del ex
        sr, ut = sr[0], ut[0]
        trow, tcol, tiles, owner_mask = trow[0], tcol[0], tiles[0], owner_mask[0]

        def body(t, carry):
            acc_red, delta, x = carry
            # dense exchange of everything accumulated since the last level —
            # the page-bouncing s.left_sum traffic of Alg. 2.
            acc_red = acc_red + jax.lax.psum(delta, AXIS)
            delta = jnp.zeros_like(delta)
            rows = jax.lax.dynamic_index_in_dim(sr, t, 0, keepdims=False)
            safe = jnp.where(rows < 0, nb, rows)
            xs = ops.batched_block_trsv(
                diag[safe], b_pad[safe] - acc_red[safe], backend=cfg.kernel_backend
            )
            x = x.at[safe].set(jnp.where(ops.bcast_trailing(rows >= 0, xs), xs, x[safe]))
            tids = jax.lax.dynamic_index_in_dim(ut, t, 0, keepdims=False)
            prods = ops.batched_block_gemv(
                tiles[tids], x[tcol[tids]], backend=cfg.kernel_backend, group=cfg.gemv_group
            )
            delta = delta.at[trow[tids]].add(prods)
            return acc_red, delta, x

        z = jnp.zeros_like(b_pad)
        _, _, x = jax.lax.fori_loop(0, T, body, (z, z, z))
        return jax.lax.psum(x * ops.bcast_trailing(owner_mask, x), AXIS)[:nb]

    return fn


def _syncfree_device_fn(plan: Plan):
    """Runtime-frontier solver: no level analysis, in-degree counters drive it."""
    cfg = plan.config
    nb, B = plan.bs.nb, plan.bs.B
    zerocopy = cfg.comm == "zerocopy"
    multi = plan.n_devices > 1

    def fn(lr, trow, tcol, tiles, owner_mask, diag, indeg, exb, b_pad):
        lr = lr[0]
        trow, tcol, tiles, owner_mask = trow[0], tcol[0], tiles[0], owner_mask[0]
        me = jax.lax.axis_index(AXIS) if multi else 0
        ldiag = diag[lr]
        lb = b_pad[lr]
        lown = owner_mask[lr] > 0  # valid (non-pad) local rows
        dest_mine = owner_mask[trow] > 0  # tile dest owned by this device

        def cond(state):
            return jnp.logical_not(state["done"])

        def body(state):
            acc_red, delta, cnt_red, dcnt, solved, x = (
                state["acc_red"], state["delta"], state["cnt_red"],
                state["dcnt"], state["solved"], state["x"],
            )
            # 1. frontier: owned, unsolved, all dependencies counted in
            ready = jnp.logical_and(
                jnp.logical_and(lown, jnp.logical_not(solved[lr])),
                cnt_red[lr] == indeg[lr],
            )
            # 2. solve the frontier (masked dense over local rows)
            xs = ops.batched_block_trsv(
                ldiag, lb - acc_red[lr], backend=cfg.kernel_backend
            )
            x = x.at[lr].set(jnp.where(ops.bcast_trailing(ready, xs), xs, x[lr]))
            solved = solved.at[lr].set(jnp.logical_or(solved[lr], ready))
            # 3. updates from tiles whose source column solved THIS superstep
            just = jnp.zeros((nb + 1,), jnp.bool_).at[lr].set(ready)
            tmask = just[tcol]
            prods = ops.batched_block_gemv(
                tiles, x[tcol], backend=cfg.kernel_backend, group=cfg.gemv_group
            )
            pm = jnp.where(ops.bcast_trailing(tmask, prods), prods, 0.0)
            cm = tmask.astype(jnp.int32)
            if multi:
                dm = ops.bcast_trailing(dest_mine, pm)
                acc_red = acc_red.at[trow].add(jnp.where(dm, pm, 0.0))
                cnt_red = cnt_red.at[trow].add(jnp.where(dest_mine, cm, 0))
                delta = delta.at[trow].add(jnp.where(dm, 0.0, pm))
                dcnt = dcnt.at[trow].add(jnp.where(dest_mine, 0, cm))
                # 4. exchange remote contributions
                if zerocopy:
                    red = jax.lax.psum(delta[exb], AXIS)
                    redc = jax.lax.psum(dcnt[exb], AXIS)
                    acc_red = acc_red.at[exb].add(red)
                    cnt_red = cnt_red.at[exb].add(redc)
                    delta = delta.at[exb].set(0.0)
                    dcnt = dcnt.at[exb].set(0)
                else:
                    acc_red = acc_red + jax.lax.psum(delta, AXIS)
                    cnt_red = cnt_red + jax.lax.psum(dcnt, AXIS)
                    delta = jnp.zeros_like(delta)
                    dcnt = jnp.zeros_like(dcnt)
            else:
                acc_red = acc_red.at[trow].add(pm)
                cnt_red = cnt_red.at[trow].add(cm)
            # 5. global termination check
            remaining = jnp.sum(jnp.logical_and(lown, jnp.logical_not(solved[lr])))
            if multi:
                remaining = jax.lax.psum(remaining, AXIS)
            return dict(
                acc_red=acc_red, delta=delta, cnt_red=cnt_red, dcnt=dcnt,
                solved=solved, x=x, done=remaining == 0,
            )

        zf = jnp.zeros_like(b_pad)
        zi = jnp.zeros((nb + 1,), jnp.int32)
        state = dict(
            acc_red=zf, delta=zf, cnt_red=zi, dcnt=zi,
            solved=jnp.zeros((nb + 1,), jnp.bool_), x=zf,
            done=jnp.asarray(False),
        )
        state = jax.lax.while_loop(cond, body, state)
        xg = state["x"] * ops.bcast_trailing(owner_mask, state["x"])
        if multi:
            xg = jax.lax.psum(xg, AXIS)
        return xg[:nb]

    return fn


class DistributedSolver:
    """Compiled multi-device SpTRSV for one (matrix, partition, mesh).

    One instance is compiled once and invoked many times — the amortized
    regime of preconditioned Krylov loops. ``n_solves`` counts invocations
    (each multi-RHS panel counts once: one compiled solve serves R systems).
    """

    def __init__(self, plan: Plan, mesh: jax.sharding.Mesh):
        assert mesh.devices.size == plan.n_devices, (mesh.devices.size, plan.n_devices)
        self.plan = plan
        self.mesh = mesh
        self.n_solves = 0
        nb = plan.bs.nb
        D = plan.n_devices
        owner_mask = np.zeros((D, nb + 1), np.float32)
        for d in range(D):
            owner_mask[d, :nb] = (plan.part.owner == d).astype(np.float32)
        self._owner_mask = owner_mask

        sharded = P(AXIS)
        repl = P()
        if plan.config.sched == "levelset":
            fn = (
                _levelset_device_fn(plan)
                if plan.config.comm == "zerocopy" or D == 1
                else _levelset_unified_device_fn(plan)
            )
            in_specs = (sharded,) * 6 + (repl, repl, repl)
            self._args = (plan.solve_rows, plan.upd_tiles, plan.tile_row,
                          plan.tile_col, plan.tiles, owner_mask, plan.diag,
                          plan.ex_levels)
        else:
            fn = _syncfree_device_fn(plan)
            in_specs = (sharded,) * 5 + (repl, repl, repl, repl)
            self._args = (plan.local_rows, plan.tile_row, plan.tile_col,
                          plan.tiles, owner_mask, plan.diag, plan.indeg,
                          plan.ex_boundary)
        mapped = compat.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=P(),
        )
        self._jitted = jax.jit(mapped)

    def solve_blocks(self, b_blocks: jax.Array) -> jax.Array:
        """b_blocks: (nb, B) or a multi-RHS panel (nb, B, R) -> same shape."""
        self.n_solves += 1
        b_pad = jnp.concatenate(
            [b_blocks, jnp.zeros((1,) + b_blocks.shape[1:], b_blocks.dtype)]
        )
        return self._jitted(*self._args, b_pad)

    def solve(self, b: np.ndarray) -> np.ndarray:
        """b: (n,) or (n, R) RHS panel. Transpose plans flip row order at this
        boundary (the plan was built on ``reverse_transpose(a)``)."""
        from repro.core.blocking import pad_rhs, unpad_x

        b = np.asarray(b, np.float32)
        if self.plan.transpose:
            b = b[::-1]
        b_blocks = jnp.asarray(pad_rhs(b, self.plan.bs))
        x = unpad_x(np.asarray(self.solve_blocks(b_blocks)), self.plan.bs)
        return x[::-1].copy() if self.plan.transpose else x


def sptrsv(
    a: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig = SolverConfig(), transpose: bool = False,
) -> np.ndarray:
    """One-shot convenience API: analyse, plan, solve Lx=b (or L^T x=b)."""
    if mesh is None:
        mesh = compat.make_mesh((1,), (AXIS,))
    plan = build_plan(a, int(mesh.devices.size), config, transpose=transpose)
    return DistributedSolver(plan, mesh).solve(b)
