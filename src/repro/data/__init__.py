from repro.data.pipeline import SyntheticLM, batch_for_cell
