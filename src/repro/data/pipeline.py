"""Deterministic synthetic data pipeline.

Stateless by construction: ``batch(step)`` is a pure function of
(seed, step, shape), so a restarted job resumes mid-epoch with zero data-state
checkpointing — the fault-tolerance property the training loop relies on
(DESIGN.md §3). Per-host sharding: each host materializes only its slice of
the global batch (``host_slice``), matching multi-host jax.Array creation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.global_batch, self.seq_len])
        )

    def batch(self, step: int, *, host_index: int = 0, host_count: int = 1) -> dict:
        b = self.global_batch // host_count
        rng = self._rng(step)
        # draw the full global batch then slice: identical global data regardless
        # of host topology (elastic restarts keep the data stream stable)
        toks = rng.integers(0, self.cfg.vocab, size=(self.global_batch, self.seq_len + 1),
                            dtype=np.int32)
        sl = slice(host_index * b, (host_index + 1) * b)
        out = {"tokens": toks[sl, :-1], "labels": toks[sl, 1:]}
        if self.cfg.input_kind == "embeddings":  # vision/audio stub inputs
            out["embeds"] = rng.standard_normal(
                (self.global_batch, self.seq_len, self.cfg.d_model), dtype=np.float32
            )[sl]
            del out["tokens"]
        if self.cfg.enc_layers:
            out["enc_embeds"] = rng.standard_normal(
                (self.global_batch, self.cfg.enc_seq, self.cfg.d_model), dtype=np.float32
            )[sl]
        return out


def batch_for_cell(cfg: ModelConfig, seq_len: int, global_batch: int, step: int = 0) -> dict:
    return SyntheticLM(cfg, global_batch, seq_len).batch(step)
