from repro.distributed.meshutil import make_mesh
from repro.distributed.sharding import batch_specs, cache_specs, param_specs
