"""Logical activation sharding constraints (MaxText-style).

Model code tags each activation dim with a *logical* role; the tag resolves
against the ambient mesh (``jax.set_mesh``) at trace time:

  "dp"    -> every non-model axis (pod+data), if the dim divides
  "model" -> the model axis, if the dim divides
  None    -> replicated

Without an ambient mesh (unit tests, single-device runs) this is an exact
no-op, so model code stays mesh-agnostic. Explicit constraints pin down XLA's
sharding propagation where it otherwise gives up (scan bodies, dynamic slices,
gather/scatter dispatch) — dropping one of these was measured to replicate the
flash-attention buffers across all 256 devices (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
from repro import compat
from jax.sharding import PartitionSpec as P


def constrain(x: jax.Array, *tags: str | None) -> jax.Array:
    """Tags: "dp" (non-model axes), "model", "dpm" (ALL axes — fully
    data-parallel batch, used when a layer family opts out of TP), None."""
    mesh = compat.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    assert len(tags) == x.ndim, (tags, x.shape)
    msize = mesh.shape["model"]
    dp = tuple(a for a in mesh.axis_names if a != "model")
    all_axes = tuple(mesh.axis_names)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    asize = dsize * msize
    assign = []
    for dim, tag in zip(x.shape, tags):
        if tag == "dp" and dim % dsize == 0 and dim >= dsize:
            assign.append(dp if len(dp) > 1 else dp[0])
        elif tag == "dpm" and dim % asize == 0 and dim >= asize:
            assign.append(all_axes)
        elif tag == "dpm" and dim % dsize == 0 and dim >= dsize:
            assign.append(dp if len(dp) > 1 else dp[0])  # fall back to dp
        elif tag == "model" and dim % msize == 0 and dim >= msize:
            assign.append("model")
        else:
            assign.append(None)
    return jax.lax.with_sharding_constraint(x, P(*assign))
