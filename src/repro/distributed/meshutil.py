"""Mesh construction helpers (explicit Auto axis types, device subsets)."""
from __future__ import annotations

import jax

from repro import compat


def make_mesh(shape, axes, devices=None) -> jax.sharding.Mesh:
    return compat.make_mesh(shape, axes, devices=devices)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Data-parallel axes: every mesh axis that is not the model axis."""
    return tuple(a for a in mesh.axis_names if a != "model")
