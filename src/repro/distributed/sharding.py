"""Sharding rules: TP/EP over the ``model`` axis, DP over ``pod``+``data``,
optional FSDP (ZeRO-3 style parameter sharding over the data axes).

Rules are *divisibility-aware*: each parameter kind carries a priority list of
trailing dims to shard on the model axis; the first divisible dim wins, else
the leaf stays replicated on that axis. Stacked (scan) leaves keep their
leading period axis unsharded. FSDP then shards the largest remaining
divisible dim over the data axes for leaves above ``fsdp_min_size`` — required
to fit the 400B-class MoE archs in HBM (DESIGN.md §3).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

# trailing-dim shard priorities by parameter name (TP/EP on the model axis).
# Attention shards HEADS or nothing: sub-head (hd / d_in) sharding was measured
# to defeat SPMD propagation and replicate activations — non-divisible head
# counts fall back to FSDP-only (EXPERIMENTS.md §Perf).
_RULES = {
    "embed": (0, 1),  # (vocab, d)
    "lm_head": (1, 0),  # (d, vocab)
    "wq": (1,), "wk": (1,), "wv": (1,),  # (d, H, hd): heads only
    "wo": (0,),  # (H, hd, d) row-parallel over heads
    "w1": (1,), "w3": (1,),  # mlp (d, f) col-parallel
    "w2": (0,),  # mlp (f, d) row-parallel
    "router": (1,),  # (d, E)
    "z_proj": (1,), "x_in": (1,), "xbc_proj": (1,), "dtp": (1,),  # mamba cols
    "out_proj": (0,),
    "x_proj": (0,), "dt_proj": (1,),
    "A_log": (0,), "Dskip": (0,), "dt_bias": (0,),
    "conv_w": (1,), "conv_b": (0,),
}
_MOE_RULES = {"w1": (0,), "w2": (0,), "w3": (0,)}  # (E, d, f): expert parallelism


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(str(k.idx))
    return names


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _spec_for_leaf(names, shape, mesh, model_axis, fsdp_axes, fsdp_min_size,
                   no_tp_names=frozenset()):
    name = names[-1]
    stacked = "slots" in names  # scan-stage leaves carry a leading period axis
    dims = list(shape[1:] if stacked else shape)
    assign: list = [None] * len(dims)

    in_moe = "moe" in names
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _RULES
    msize = _axis_size(mesh, model_axis)
    if name not in no_tp_names:
        for d in rules.get(name, ()):
            if d < len(dims) and dims[d] % msize == 0 and dims[d] >= msize:
                assign[d] = model_axis
                break

    # FSDP: shard the largest remaining divisible dim over the data axes.
    # Size gate uses the FULL leaf (incl. the stacked period axis) — memory is
    # what matters, and scan stages stack 24-88 layers into one leaf.
    if fsdp_axes and len(dims) >= 2:
        size = 1
        for s in shape:
            size *= s
        if size >= fsdp_min_size:
            fsize = _axis_size(mesh, fsdp_axes)
            cands = sorted(
                (i for i in range(len(dims)) if assign[i] is None),
                key=lambda i: -dims[i],
            )
            for i in cands:
                if dims[i] % fsize == 0 and dims[i] >= fsize:
                    assign[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                    break
    if stacked:
        assign = [None] + assign
    return P(*assign)


# weight names that lose their model-axis (TP) assignment when a config opts
# its SSM layers out of tensor parallelism (ModelConfig.ssm_tp=False)
SSM_WEIGHT_NAMES = frozenset({
    "x_in", "z_proj", "bc_proj", "dtp", "out_proj", "x_proj", "dt_proj",
    "conv_w", "conv_b", "conv_bc_w", "conv_bc_b", "A_log", "Dskip", "dt_bias",
})


def param_specs(
    params, mesh, *, model_axis: str = "model",
    fsdp_axes: tuple[str, ...] = (), fsdp_min_size: int = 1 << 24,
    no_tp_names: frozenset = frozenset(),
):
    """PartitionSpec pytree for a params (or optimizer-state) tree."""

    def leaf_spec(path, leaf):
        names = _path_names(path)
        return _spec_for_leaf(
            names, leaf.shape, mesh, model_axis, fsdp_axes, fsdp_min_size,
            no_tp_names,
        )

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_specs(batch, mesh, *, dp_axes: tuple[str, ...]):
    """Shard dim0 (global batch) of every batch leaf over the DP axes."""
    dsize = _axis_size(mesh, dp_axes)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def leaf_spec(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % dsize == 0 and leaf.shape[0] >= dsize:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(leaf_spec, batch)


def cache_specs(cache, mesh, *, model_axis: str = "model", dp_axes: tuple[str, ...] = ("data",)):
    """Decode-cache specs: batch over DP; long KV sequence / SSM channels over model.

    KV leaves are (B, S, K, hd) (+ leading stack axis); SSM ``h`` is
    (B, nh|di, N[, hp]); conv states (B, K-1, C). Dim choice is again
    divisibility-gated so batch=1 long-context cells degrade gracefully.
    """
    dsize = _axis_size(mesh, dp_axes)
    msize = _axis_size(mesh, model_axis)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        stacked = "slots" in names
        dims = list(leaf.shape[1:] if stacked else leaf.shape)
        assign: list = [None] * len(dims)
        if name == "pos" or not dims:
            return P(*([None] * leaf.ndim))
        if dims[0] % dsize == 0 and dims[0] >= dsize:
            assign[0] = dp  # batch
        if name in ("k", "v") and len(dims) == 4:
            if dims[1] % msize == 0:  # cache sequence dim (decode SP)
                assign[1] = model_axis
            elif dims[2] % msize == 0:  # kv heads
                assign[2] = model_axis
        elif name in ("h", "conv") and len(dims) >= 2:
            for d in (1, 2):
                if d < len(dims) and dims[d] % msize == 0 and dims[d] >= msize:
                    assign[d] = model_axis
                    break
        if stacked:
            assign = [None] + assign
        return P(*assign)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
