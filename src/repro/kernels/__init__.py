"""Pallas TPU kernels for the SpTRSV hot loop (validated in interpret mode)."""
