"""Pallas TPU kernel: batched block GEMV for off-diagonal tile updates.

The update half of the paper's solve-update phase (Alg. 3 lines 29–35): each
strictly-lower tile L[r,c] contributes ``acc[r] += L[r,c] @ x[c]``. The kernel
computes the per-tile products on the MXU; the scatter-add over destination
rows is a segment-sum outside the kernel (racing scatter across grid programs
is not expressible portably — destinations are combined with a deterministic
jnp segment reduction, mirroring the paper's device-side atomics).

``block_gemv_grouped`` processes G tiles per grid program so each MXU call is
a (G*B, B) × (B,) batched matvec — the grouped layout raises MXU utilization
(§Perf hillclimb knob).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemv_kernel(t_ref, x_ref, o_ref):
    # t_ref: (1,B,B), x_ref: (1,B), o_ref: (1,B)
    o_ref[0, :] = jnp.dot(
        t_ref[0], x_ref[0, :], preferred_element_type=t_ref.dtype
    )


def _gemv_grouped_kernel(t_ref, x_ref, o_ref):
    # t_ref: (G,B,B), x_ref: (G,B), o_ref: (G,B) — one fused batched matvec
    o_ref[...] = jnp.einsum(
        "gij,gj->gi", t_ref[...], x_ref[...], preferred_element_type=t_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gemv(tiles: jax.Array, xs: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Per-tile products: tiles (m,B,B) @ xs (m,B) -> (m,B)."""
    m, B, _ = tiles.shape
    return pl.pallas_call(
        _gemv_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, B), tiles.dtype),
        interpret=interpret,
    )(tiles, xs)


def _gemm_kernel(t_ref, x_ref, o_ref):
    # t_ref: (1,B,B), x_ref: (1,B,R), o_ref: (1,B,R) — per-tile (B,B)@(B,R)
    o_ref[0] = jnp.dot(t_ref[0], x_ref[0], preferred_element_type=t_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gemm(tiles: jax.Array, xs: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Multi-RHS tile products: tiles (m,B,B) @ xs (m,B,R) -> (m,B,R).

    The RHS panel turns each tile's MXU call from a matvec into a (B,B)@(B,R)
    matmul — the serving-scale batching path (one compiled solve, R systems).
    """
    m, B, _ = tiles.shape
    R = xs.shape[-1]
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B, R), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B, R), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, B, R), tiles.dtype),
        interpret=interpret,
    )(tiles, xs)


@functools.partial(jax.jit, static_argnames=("group", "interpret"))
def block_gemv_grouped(
    tiles: jax.Array, xs: jax.Array, *, group: int = 8, interpret: bool = False
) -> jax.Array:
    """Same contract as block_gemv but G tiles per grid program (MXU batching)."""
    m, B, _ = tiles.shape
    pad = (-m) % group
    if pad:
        tiles = jnp.concatenate([tiles, jnp.zeros((pad, B, B), tiles.dtype)])
        xs = jnp.concatenate([xs, jnp.zeros((pad, B), xs.dtype)])
    mg = tiles.shape[0]
    out = pl.pallas_call(
        _gemv_grouped_kernel,
        grid=(mg // group,),
        in_specs=[
            pl.BlockSpec((group, B, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((group, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((group, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mg, B), tiles.dtype),
        interpret=interpret,
    )(tiles, xs)
    return out[:m]
