"""Pallas TPU kernel: batched dense lower-triangular block solve (block-TRSV).

TPU mapping of the paper's per-component solve (DESIGN.md §5.3): a wavefront's
diagonal tiles are solved as dense B×B forward substitutions, one grid program
per tile, with the whole tile resident in VMEM.

Two in-kernel algorithms:
* ``row-sweep``  — B scalar steps, each a masked VPU row·x dot (O(B) vector ops).
* ``panel``      — processes P=8 rows per step: a tiny unrolled P×P triangle
  followed by a rank-P MXU update of the remaining rhs. ~P× fewer sequential
  steps; this is the §Perf variant (hillclimbed in EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trsv_rowsweep_kernel(l_ref, r_ref, x_ref):
    # l_ref: (1,B,B)  r_ref/x_ref: (1,B)
    B = l_ref.shape[-1]
    L = l_ref[0]  # (B,B) loaded to VMEM/registers
    r = r_ref[...]  # (1,B)
    col = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)

    def body(i, x):
        # partial dot over solved prefix: sum_j<i L[i,j] * x[j]
        li = jax.lax.dynamic_slice(L, (i, 0), (1, B))  # (1,B) row i
        s = jnp.sum(jnp.where(col < i, li * x, 0.0))
        lii = jnp.sum(jnp.where(col == i, li, 0.0))
        ri = jnp.sum(jnp.where(col == i, r, 0.0))
        xi = (ri - s) / lii
        return jnp.where(col == i, xi, x)

    x_ref[...] = jax.lax.fori_loop(0, B, body, jnp.zeros((1, B), l_ref.dtype))


def _trsv_panel_kernel(l_ref, r_ref, x_ref, *, panel: int):
    # Panel forward substitution: solve P rows with the row sweep, then one
    # (B,P)@(P,) MXU-shaped rank-P update of the remaining rhs.
    B = l_ref.shape[-1]
    P = panel
    L = l_ref[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)

    def outer(p, carry):
        r, x = carry  # both (1,B); r is the running rhs (updated by prior panels)
        base = p * P

        def inner(q, x):
            i = base + q
            li = jax.lax.dynamic_slice(L, (i, 0), (1, B))
            in_panel_prefix = jnp.logical_and(col >= base, col < i)
            s = jnp.sum(jnp.where(in_panel_prefix, li * x, 0.0))
            lii = jnp.sum(jnp.where(col == i, li, 0.0))
            ri = jnp.sum(jnp.where(col == i, r, 0.0))
            xi = (ri - s) / lii
            return jnp.where(col == i, xi, x)

        x = jax.lax.fori_loop(0, P, inner, x)
        # rank-P update of the trailing rhs: r -= L[:, base:base+P] @ x[base:base+P]
        Lp = jax.lax.dynamic_slice(L, (0, base), (B, P))  # (B,P)
        xp = jax.lax.dynamic_slice(x, (0, base), (1, P))  # (1,P)
        upd = jnp.dot(Lp, xp[0], preferred_element_type=jnp.float32)  # (B,)
        r = jnp.where(col >= base + P, r - upd[None, :], r)
        return r, x

    _, x = jax.lax.fori_loop(
        0, B // P, outer, (r_ref[...], jnp.zeros((1, B), l_ref.dtype))
    )
    x_ref[...] = x


def _trsm_rowsweep_kernel(l_ref, r_ref, x_ref):
    # Multi-RHS row sweep: l_ref (1,B,B), r_ref/x_ref (1,B,R). Same forward
    # substitution as _trsv_rowsweep_kernel, but the per-row partial dot is a
    # masked (1,B)@(B,R) matmul — one MXU call amortized over all R systems.
    B = l_ref.shape[-1]
    R = r_ref.shape[-1]
    L = l_ref[0]  # (B,B)
    r = r_ref[0]  # (B,R)
    col = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)

    def body(i, x):
        li = jax.lax.dynamic_slice(L, (i, 0), (1, B))  # (1,B) row i
        s = jnp.dot(
            jnp.where(col < i, li, 0.0), x, preferred_element_type=jnp.float32
        )  # (1,R) partial dots over the solved prefix, all RHS at once
        lii = jnp.sum(jnp.where(col == i, li, 0.0))
        ri = jax.lax.dynamic_slice(r, (i, 0), (1, R))  # (1,R)
        xi = (ri - s) / lii
        return jnp.where(row == i, xi, x)

    x_ref[0] = jax.lax.fori_loop(0, B, body, jnp.zeros((B, R), l_ref.dtype))


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_trsm(diag: jax.Array, rhs: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Batched multi-RHS solve: (k,B,B) tiles × (k,B,R) panels -> (k,B,R)."""
    k, B, _ = diag.shape
    R = rhs.shape[-1]
    return pl.pallas_call(
        _trsm_rowsweep_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, B, R), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B, R), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, B, R), diag.dtype),
        interpret=interpret,
    )(diag, rhs)


@functools.partial(jax.jit, static_argnames=("algorithm", "panel", "interpret"))
def block_trsv(
    diag: jax.Array,
    rhs: jax.Array,
    *,
    algorithm: str = "rowsweep",
    panel: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Batched solve of k dense lower-triangular tiles: (k,B,B),(k,B)->(k,B)."""
    k, B, _ = diag.shape
    if algorithm == "rowsweep":
        kernel = _trsv_rowsweep_kernel
    elif algorithm == "panel":
        assert B % panel == 0
        kernel = functools.partial(_trsv_panel_kernel, panel=panel)
    else:
        raise ValueError(algorithm)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda i: (i, 0, 0)),  # one tile in VMEM per program
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, B), diag.dtype),
        interpret=interpret,
    )(diag, rhs)
