"""Public jit'd kernel wrappers with backend dispatch.

Backends:
* ``reference`` — pure jnp (XLA) oracles from :mod:`repro.kernels.ref`; the
  default on CPU where Pallas interpret mode would be pure-Python slow.
* ``pallas``    — the TPU kernels; on CPU they run in interpret mode
  (used by tests to validate kernel semantics), on TPU they compile natively.
* ``fused``     — an *executor-level* backend: the levelset executors run the
  whole compacted schedule in one Pallas superstep megakernel
  (:mod:`repro.kernels.superstep`) and syncfree runs frontier-bucketed.
  Individual block ops called under it fall back to the platform default.
* ``fused_streamed`` — the megakernel with the streaming HBM tile store:
  ``diag``/``tiles`` live in ``ANY``/HBM and each level's schedule slice is
  double-buffered into VMEM by async DMA, so VMEM residency scales with the
  widest level slice instead of the total tile count. Plain ``fused`` also
  auto-upgrades to streaming when the resident store would exceed
  ``core.solver.stream_vmem_limit()``. For ``sched="syncfree"`` it behaves
  exactly like ``fused`` (the frontier executor has no resident tile problem).

Every op accepts either a single right-hand side per tile (``(k, B)``) or a
multi-RHS panel (``(k, B, R)``) — the panel path serves R systems from one
compiled solve (dispatched here by rhs rank).

Select globally with env ``REPRO_KERNEL_BACKEND`` or per-call with ``backend=``.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.block_spmv import block_gemm, block_gemv, block_gemv_grouped
from repro.kernels.block_trsv import block_trsm, block_trsv

BACKENDS = ("reference", "pallas", "fused", "fused_streamed")

# executor-level backends that select the megakernel levelset path (and the
# frontier-bucketed syncfree executor)
FUSED_BACKENDS = ("fused", "fused_streamed")


def _default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def executor_backend(backend: str | None = None) -> str:
    """Resolve the executor-level backend (``fused`` selects the megakernel
    levelset path / frontier-bucketed syncfree in ``core.solver``)."""
    b = backend or _default_backend()
    if b not in BACKENDS:
        raise ValueError(f"unknown kernel backend: {b!r} (expected {BACKENDS})")
    return b


def is_fused(backend: str | None = None) -> bool:
    """Whether the resolved executor backend is a fused (megakernel) variant."""
    return executor_backend(backend) in FUSED_BACKENDS


def op_backend(backend: str | None = None) -> str:
    """Resolve the per-op backend; the fused variants degrade to the platform
    default (pallas on TPU, reference elsewhere) for the residual batched ops."""
    b = executor_backend(backend)
    if b in FUSED_BACKENDS:
        b = "pallas" if jax.default_backend() == "tpu" else "reference"
    return b


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def interpret_mode() -> bool:
    """Whether Pallas kernels (incl. the superstep megakernel) run interpreted."""
    return _interpret()


def bcast_trailing(mask: jax.Array, x: jax.Array) -> jax.Array:
    """Reshape ``mask`` with trailing singleton dims so it broadcasts against
    ``x`` — lets solver code stay agnostic to single- vs multi-RHS shapes."""
    return mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))


def batched_block_trsv(diag: jax.Array, rhs: jax.Array, *, backend: str | None = None,
                       algorithm: str = "rowsweep") -> jax.Array:
    backend = op_backend(backend)
    if backend == "reference":
        return ref.block_trsv_ref(diag, rhs)
    if rhs.ndim == 3:
        return block_trsm(diag, rhs, interpret=_interpret())
    return block_trsv(diag, rhs, algorithm=algorithm, interpret=_interpret())


def batched_block_gemv(tiles: jax.Array, xs: jax.Array, *, backend: str | None = None,
                       group: int = 0) -> jax.Array:
    backend = op_backend(backend)
    if backend == "reference":
        return ref.block_gemv_ref(tiles, xs)
    if xs.ndim == 3:
        return block_gemm(tiles, xs, interpret=_interpret())
    if group > 1:
        return block_gemv_grouped(tiles, xs, group=group, interpret=_interpret())
    return block_gemv(tiles, xs, interpret=_interpret())
