"""Public jit'd kernel wrappers with backend dispatch.

Backends:
* ``reference`` — pure jnp (XLA) oracles from :mod:`repro.kernels.ref`; the
  default on CPU where Pallas interpret mode would be pure-Python slow.
* ``pallas``    — the TPU kernels; on CPU they run in interpret mode
  (used by tests to validate kernel semantics), on TPU they compile natively.

Select globally with env ``REPRO_KERNEL_BACKEND`` or per-call with ``backend=``.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.block_spmv import block_gemv, block_gemv_grouped
from repro.kernels.block_trsv import block_trsv


def _default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def batched_block_trsv(diag: jax.Array, rhs: jax.Array, *, backend: str | None = None,
                       algorithm: str = "rowsweep") -> jax.Array:
    backend = backend or _default_backend()
    if backend == "reference":
        return ref.block_trsv_ref(diag, rhs)
    return block_trsv(diag, rhs, algorithm=algorithm, interpret=_interpret())


def batched_block_gemv(tiles: jax.Array, xs: jax.Array, *, backend: str | None = None,
                       group: int = 0) -> jax.Array:
    backend = backend or _default_backend()
    if backend == "reference":
        return ref.block_gemv_ref(tiles, xs)
    if group > 1:
        return block_gemv_grouped(tiles, xs, group=group, interpret=_interpret())
    return block_gemv(tiles, xs, interpret=_interpret())
