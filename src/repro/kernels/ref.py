"""Pure-jnp oracles for the Pallas kernels (independent implementations).

These are the ground truth for tests/*: every Pallas kernel must match its
oracle over a sweep of shapes and dtypes (interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_trsv_ref(diag: jax.Array, rhs: jax.Array) -> jax.Array:
    """Batched dense lower-triangular solve.

    rhs may be a single vector per tile ``(k, B)`` or a multi-RHS panel
    ``(k, B, R)`` — one solve amortized over R right-hand sides.
    """
    multi = rhs.ndim == 3
    r = rhs if multi else rhs[..., None]
    sol = jax.lax.linalg.triangular_solve(
        diag, r, left_side=True, lower=True, transpose_a=False
    )
    return sol if multi else sol[..., 0]


def block_gemv_ref(tiles: jax.Array, xs: jax.Array) -> jax.Array:
    """Batched tile*vector: tiles (m,B,B), xs (m,B) or (m,B,R) panels."""
    if xs.ndim == 3:
        return jnp.einsum("mij,mjr->mir", tiles, xs)
    return jnp.einsum("mij,mj->mi", tiles, xs)


def fused_level_ref(
    diag: jax.Array,  # (k,B,B) diagonal tiles of the wavefront rows
    rhs: jax.Array,  # (k,B)   b - acc for those rows
    tiles: jax.Array,  # (m,B,B) off-diagonal tiles sourced at this wavefront
    src: jax.Array,  # (m,) index into the wavefront's k solves for each tile's column
) -> tuple[jax.Array, jax.Array]:
    """Solve a wavefront then produce the per-tile updates it triggers."""
    x = block_trsv_ref(diag, rhs)
    prods = block_gemv_ref(tiles, x[src])
    return x, prods
