"""Fused Pallas superstep megakernel for the compacted levelset schedule.

The paper's zero-copy SpTRSV wins by replacing coarse per-wavefront kernel
launches with fine-grained dependency-aware execution resident on the device.
The ``lax.switch`` compacted executor (``core.solver``) is the XLA analogue of
the *launch-per-superstep* baseline: every level re-dispatches a gather, a
batched TRSV, a batched GEMV and a scatter-add as separate ops, plus one
``switch`` branch per width-bucket combo. This module is the persistent-kernel
analogue: **one** ``pallas_call`` executes a whole run of levels.

Scalar-prefetch layout
----------------------
The ragged compacted schedule rides in as scalar-prefetch operands (SMEM on
TPU, available before the kernel body runs, so schedule reads never touch
HBM):

* ``seg``  ``(2,)``   — ``[first_level, n_active_levels]`` of this launch.
* ``off``  ``(T, 3)`` — per-level start offsets into the three flats.
* ``wid``  ``(T, 3)`` — per-level bucket widths ``(w_solve, w_upd, w_ex)``.
* ``sr``   ``(S,)``   — flat solve rows (device-local), pad ``-1``.
* ``ut``   ``(U,)``   — flat update tile slots (device-local), pad ``ML``.
* ``trow``/``tcol`` ``(ML+1,)`` — per-tile destination row / source column.

Grid = one program per level; program ``p`` executes level ``seg[0] + p``
(programs beyond ``seg[1]`` are inert padding, which lets a ``fori_loop`` over
variable-length segments reuse one traced launch). TPU grid programs run
sequentially on a core, so the carry buffers (``acc``, ``x``, and ``delta``
for the unified split) persist in the output windows across levels — level
``t+1`` reads the partial sums level ``t`` wrote without any HBM round-trip.
Program 0 copies the incoming carries into the output windows (one copy per
launch; see the aliasing note in :func:`superstep_call`).

Each program walks its level's slice of the schedule with in-kernel loops
bounded by the *bucket width* (dynamic trip counts, so a 3-row level costs a
width-4 loop, not the global max): per row a dense forward substitution of the
diagonal tile, then per tile a ``(B,B)@(B[,R])`` MXU product accumulated into
the destination row of ``acc`` (or ``delta``). The in-kernel arithmetic
mirrors ``block_trsv``/``block_trsm``/``block_gemv``/``block_gemm``
expression-for-expression, so the fused kernel is bit-exact with the
``lax.switch`` executor running the per-op Pallas backend — the property
``tests/test_superstep.py`` pins down in interpret mode.

Collectives cannot live inside a Pallas kernel, so the boundary exchange
splits the level range into *segments* (``core.solver.fused_segments``): one
launch per run of levels between exchanges. Single-device plans and empty
cuts fuse the entire solve into exactly one launch.

All operands ride in whole (full-array block specs): the plans this repo
builds keep ``diag``/``tiles`` well under VMEM at the benched scales; a
streaming variant would move the tile store to ``ANY`` and double-buffer DMA
slices per level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_PREFETCH = 7  # seg, off, wid, sr, ut, trow, tcol


def _solve_tile(L, rhs):
    """(B,B) lower-triangular solve of one rhs vector (B,).

    Mirrors ``block_trsv._trsv_rowsweep_kernel`` op-for-op (masked full-row
    dots over a (1,B) working vector) so results are bit-identical.
    """
    B = L.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)
    r = rhs.reshape(1, B)

    def body(i, x):
        li = jax.lax.dynamic_slice(L, (i, 0), (1, B))
        s = jnp.sum(jnp.where(col < i, li * x, 0.0))
        lii = jnp.sum(jnp.where(col == i, li, 0.0))
        ri = jnp.sum(jnp.where(col == i, r, 0.0))
        xi = (ri - s) / lii
        return jnp.where(col == i, xi, x)

    return jax.lax.fori_loop(0, B, body, jnp.zeros((1, B), L.dtype))[0]


def _solve_tile_panel(L, rhs):
    """(B,B) solve of a (B,R) panel; mirrors ``_trsm_rowsweep_kernel``."""
    B = L.shape[-1]
    R = rhs.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)

    def body(i, x):
        li = jax.lax.dynamic_slice(L, (i, 0), (1, B))
        s = jnp.dot(
            jnp.where(col < i, li, 0.0), x, preferred_element_type=jnp.float32
        )
        lii = jnp.sum(jnp.where(col == i, li, 0.0))
        ri = jax.lax.dynamic_slice(rhs, (i, 0), (1, R))
        xi = (ri - s) / lii
        return jnp.where(row == i, xi, x)

    return jax.lax.fori_loop(0, B, body, jnp.zeros((B, R), L.dtype))


def _superstep_kernel(
    seg_ref, off_ref, wid_ref, sr_ref, ut_ref, trow_ref, tcol_ref,
    diag_ref, tiles_ref, b_ref, *io_refs, multi: bool, split_delta: bool,
):
    if split_delta:
        acc_in, delta_in, x_in, acc_ref, delta_ref, x_ref = io_refs
    else:
        acc_in, x_in, acc_ref, x_ref = io_refs
        delta_ref = acc_ref  # tile updates land in acc (the zerocopy/local carry)
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _():  # materialize the donated carries in the output windows
        acc_ref[...] = acc_in[...]
        x_ref[...] = x_in[...]
        if split_delta:
            delta_ref[...] = delta_in[...]

    t = seg_ref[0] + p

    @pl.when(p < seg_ref[1])
    def _():
        # --- solve this level's owned rows (dynamic trip = bucket width) ---
        o_s = off_ref[t, 0]

        def solve_one(i, carry):
            r = sr_ref[o_s + i]

            @pl.when(r >= 0)
            def _():
                L = diag_ref[r]
                rhs = b_ref[r] - acc_ref[r]
                x_ref[r] = _solve_tile_panel(L, rhs) if multi else _solve_tile(L, rhs)

            return carry

        jax.lax.fori_loop(0, wid_ref[t, 0], solve_one, 0)

        # --- owned-tile updates sourced at this level ---
        o_u = off_ref[t, 1]

        def upd_one(j, carry):
            tid = ut_ref[o_u + j]
            # keep the MXU product a standalone dot on materialized operands:
            # letting XLA fuse the gathers or the accumulate into the dot
            # changes its reduction codegen by 1 ulp vs the batched per-op
            # kernels, breaking switch-executor bit-exactness
            tile, xv = jax.lax.optimization_barrier(
                (tiles_ref[tid], x_ref[tcol_ref[tid]])
            )
            prod = jax.lax.optimization_barrier(
                jnp.dot(tile, xv, preferred_element_type=tile.dtype)
            )
            rd = trow_ref[tid]
            delta_ref[rd] = delta_ref[rd] + prod
            return carry

        jax.lax.fori_loop(0, wid_ref[t, 1], upd_one, 0)


@functools.partial(
    jax.jit, static_argnames=("grid", "split_delta", "interpret")
)
def superstep_call(
    seg: jax.Array,  # (2,) int32 [first_level, n_active_levels]
    off: jax.Array,  # (T, 3) int32 level offsets into the flats
    wid: jax.Array,  # (T, 3) int32 level bucket widths
    sr: jax.Array,  # (S,) int32 flat solve rows, pad -1
    ut: jax.Array,  # (U,) int32 flat tile slots, pad ML
    trow: jax.Array,  # (ML+1,) int32
    tcol: jax.Array,  # (ML+1,) int32
    diag: jax.Array,  # (nb+1, B, B)
    tiles: jax.Array,  # (ML+1, B, B)
    b_pad: jax.Array,  # (nb+1, B) or (nb+1, B, R)
    acc: jax.Array,
    x: jax.Array,
    delta: jax.Array | None = None,
    *,
    grid: int,
    split_delta: bool = False,
    interpret: bool = False,
):
    """One fused launch executing ``grid`` levels starting at ``seg[0]``.

    Returns the updated ``(acc, x)`` carry, or ``(acc, delta, x)`` when
    ``split_delta`` (the unified executor's not-yet-exchanged contributions
    accumulate in ``delta`` while solves read ``acc``).
    """
    multi = b_pad.ndim == 3
    assert (delta is not None) == split_delta
    carry_in = (acc, delta, x) if split_delta else (acc, x)
    n_carry = len(carry_in)

    def vec_spec(a):
        zeros = (0,) * a.ndim
        return pl.BlockSpec(a.shape, lambda p, *refs: zeros)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=N_PREFETCH,
        grid=(grid,),
        in_specs=[vec_spec(a) for a in (diag, tiles, b_pad, *carry_in)],
        out_specs=[vec_spec(a) for a in carry_in],
    )
    # The carries are deliberately NOT donated via input_output_aliases:
    # callers init them from one zeroed array that XLA may CSE into a single
    # buffer, and two must-alias outputs sharing one operand buffer would let
    # x_ref writes clobber acc_ref on hardware. Program 0's explicit copy-in
    # already pays the one copy per launch that donation would have saved.
    kernel = functools.partial(
        _superstep_kernel, multi=multi, split_delta=split_delta
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in carry_in),
        interpret=interpret,
    )(
        seg.astype(jnp.int32), off.astype(jnp.int32), wid.astype(jnp.int32),
        sr.astype(jnp.int32), ut.astype(jnp.int32), trow.astype(jnp.int32),
        tcol.astype(jnp.int32), diag, tiles, b_pad, *carry_in,
    )
    return out
