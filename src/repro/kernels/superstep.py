"""Fused Pallas superstep megakernel for the compacted levelset schedule.

The paper's zero-copy SpTRSV wins by replacing coarse per-wavefront kernel
launches with fine-grained dependency-aware execution resident on the device.
The ``lax.switch`` compacted executor (``core.solver``) is the XLA analogue of
the *launch-per-superstep* baseline: every level re-dispatches a gather, a
batched TRSV, a batched GEMV and a scatter-add as separate ops, plus one
``switch`` branch per width-bucket combo. This module is the persistent-kernel
analogue: **one** ``pallas_call`` executes a whole run of levels.

Scalar-prefetch layout
----------------------
The ragged compacted schedule rides in as scalar-prefetch operands (SMEM on
TPU, available before the kernel body runs, so schedule reads never touch
HBM):

* ``seg``  ``(2,)``   — ``[first_step, n_active_steps]`` of this launch.
* ``off``  ``(T, 3)`` — per-level start offsets into the three flats.
* ``wid``  ``(T, 3)`` — per-level bucket widths ``(w_solve, w_upd, w_ex)``.
* ``stp``  ``(n_steps+1,)`` — level offsets of the supersteps: step ``s``
  covers levels ``[stp[s], stp[s+1])``. Identity (``arange``) for levelset;
  the DAG-partition merge pass's coarsening for ``sched="dagpart"``.
* ``sr``   ``(S,)``   — flat solve rows (device-local), pad ``-1``.
* ``ut``   ``(U,)``   — flat update tile slots (device-local), pad ``ML``.
* ``trow``/``tcol`` ``(ML+1,)`` — per-tile destination row / source column.

Grid = one program per *superstep*; program ``p`` executes the levels of step
``seg[0] + p`` in order (programs beyond ``seg[1]`` are inert padding, which
lets a ``fori_loop`` over variable-length segments reuse one traced launch).
A merged step's levels run back-to-back inside one program — the sequential
rowsweep is exactly what makes intra-step dependencies legal. TPU grid
programs run sequentially on a core, so the carry buffers (``acc``, ``x``,
and ``delta`` for the unified split) persist in the output windows across
steps — step ``s+1`` reads the partial sums step ``s`` wrote without any HBM
round-trip. Program 0 copies the incoming carries into the output windows
(one copy per launch; see the aliasing note in :func:`superstep_call`).

Each program walks its levels' slices of the schedule with in-kernel loops
bounded by the *bucket width* (dynamic trip counts, so a 3-row level costs a
width-4 loop, not the global max): per row a dense forward substitution of the
diagonal tile, then per tile a ``(B,B)@(B[,R])`` MXU product accumulated into
the destination row of ``acc`` (or ``delta``). The in-kernel arithmetic
mirrors ``block_trsv``/``block_trsm``/``block_gemv``/``block_gemm``
expression-for-expression, so the fused kernel is bit-exact with the
``lax.switch`` executor running the per-op Pallas backend — the property
``tests/test_superstep.py`` pins down in interpret mode.

Collectives cannot live inside a Pallas kernel, so the boundary exchange
splits the level range into *segments* (``core.solver.fused_segments``): one
launch per run of levels between exchanges. Single-device plans and empty
cuts fuse the entire solve into exactly one launch.

Resident vs streamed stores
---------------------------
In the **resident** variant all operands ride in whole (full-array block
specs): fine while ``diag``/``tiles`` fit VMEM, but the footprint grows with
the *total* tile count, which caps the matrix sizes the fused hot path can
serve. The **streamed** variant (``stream=True``) is the production-scale
path: ``diag``/``tiles`` arrive *schedule-ordered* (level ``t``'s slice is
contiguous at ``off[t]`` — exactly the compacted flat layout, so a merged
step's slice is contiguous too) and live in ``ANY``/HBM; each grid program
double-buffers its step's slices into two VMEM scratch buffers with async
DMA, prefetching step ``s+1`` while step ``s`` computes. VMEM residency then
scales with the *widest superstep slice* (max summed widths over the step
table), not the total tile store, and the DMA engine sees exactly one
contiguous burst per step per store. The DMA sizes branch over the distinct
step widths (a
static ladder of ≤ ``MAX_BUCKETS`` sizes), so the bytes moved per solve equal
the compacted schedule footprint — no pad-to-max traffic. The in-kernel
arithmetic is shared with the resident variant op-for-op, so streamed,
resident, and ``lax.switch`` execution are mutually bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_PREFETCH = 8  # seg, off, wid, stp, sr, ut, trow, tcol

# Trace-time record of the most recent streamed launch's VMEM scratch shapes
# (diag_buf/tile_buf) — lets tests assert the streaming contract (buffers
# sized by the max per-level slice, never the total store) without digging
# into lowered HLO.
LAST_STREAM_ALLOC: dict = {}


def stream_scratch_shapes(solve_widths: tuple, upd_widths: tuple, B: int
                          ) -> tuple[tuple, tuple]:
    """The streaming kernel's VMEM scratch allocation rule: double-buffered
    slices sized by the widest entry of each DMA ladder — the distinct
    per-superstep widths — (``(2, W, B, B)`` per store, never the total store
    size). This is the single source shared by
    :func:`superstep_call` and the static plan verifier
    (``repro.verify.contracts``), so the lint checks the allocation the kernel
    actually performs rather than a re-derivation of it."""
    WS = max([w for w in solve_widths if w > 0] or [1])
    WU = max([w for w in upd_widths if w > 0] or [1])
    return (2, WS, B, B), (2, WU, B, B)


def _solve_tile(L, rhs):
    """(B,B) lower-triangular solve of one rhs vector (B,).

    Mirrors ``block_trsv._trsv_rowsweep_kernel`` op-for-op (masked full-row
    dots over a (1,B) working vector) so results are bit-identical.
    """
    B = L.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)
    r = rhs.reshape(1, B)

    def body(i, x):
        li = jax.lax.dynamic_slice(L, (i, 0), (1, B))
        s = jnp.sum(jnp.where(col < i, li * x, 0.0))
        lii = jnp.sum(jnp.where(col == i, li, 0.0))
        ri = jnp.sum(jnp.where(col == i, r, 0.0))
        xi = (ri - s) / lii
        return jnp.where(col == i, xi, x)

    return jax.lax.fori_loop(0, B, body, jnp.zeros((1, B), L.dtype))[0]


def _solve_tile_panel(L, rhs):
    """(B,B) solve of a (B,R) panel; mirrors ``_trsm_rowsweep_kernel``."""
    B = L.shape[-1]
    R = rhs.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)

    def body(i, x):
        li = jax.lax.dynamic_slice(L, (i, 0), (1, B))
        s = jnp.dot(
            jnp.where(col < i, li, 0.0), x, preferred_element_type=jnp.float32
        )
        lii = jnp.sum(jnp.where(col == i, li, 0.0))
        ri = jax.lax.dynamic_slice(rhs, (i, 0), (1, R))
        xi = (ri - s) / lii
        return jnp.where(row == i, xi, x)

    return jax.lax.fori_loop(0, B, body, jnp.zeros((B, R), L.dtype))


def _superstep_kernel(
    seg_ref, off_ref, wid_ref, stp_ref, sr_ref, ut_ref, trow_ref, tcol_ref,
    diag_ref, tiles_ref, b_ref, *io_refs, multi: bool, split_delta: bool,
    stream: bool = False, solve_widths: tuple = (), upd_widths: tuple = (),
):
    """Shared kernel body for the resident and streamed variants.

    Resident: ``diag_ref``/``tiles_ref`` are whole VMEM arrays indexed by row
    / tile slot. Streamed: they are *schedule-ordered* HBM (``ANY``) stores —
    slot ``k`` of the solve/update flats corresponds to entry ``k`` — and each
    superstep's contiguous slice is DMA'd into the double-buffered VMEM
    scratch (``dbuf``/``tbuf``) at its exact summed width (one ``pl.when``
    branch per distinct width in the static ladder, so start/wait always
    agree on size).
    """
    if stream:
        *io_refs, dbuf, tbuf, dsem, tsem = io_refs
    if split_delta:
        acc_in, delta_in, x_in, acc_ref, delta_ref, x_ref = io_refs
    else:
        acc_in, x_in, acc_ref, x_ref = io_refs
        delta_ref = acc_ref  # tile updates land in acc (the zerocopy/local carry)
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _():  # materialize the donated carries in the output windows
        acc_ref[...] = acc_in[...]
        x_ref[...] = x_in[...]
        if split_delta:
            delta_ref[...] = delta_in[...]

    s = seg_ref[0] + p
    slot = jax.lax.rem(p, 2)

    if stream:

        def _step_copies(q, sl):
            """(predicate, async_copy) pairs moving superstep ``seg[0]+q``'s
            schedule slices into scratch slot ``sl``. A step's levels are
            consecutive in the flats, so one burst per store covers the whole
            merge group; one candidate per distinct per-step summed width,
            predicated on the step's actual total."""
            sq = seg_ref[0] + q
            t0 = stp_ref[sq]
            t1 = stp_ref[sq + 1] - 1  # last level of the step (steps non-empty)
            wsq = off_ref[t1, 0] + wid_ref[t1, 0] - off_ref[t0, 0]
            wuq = off_ref[t1, 1] + wid_ref[t1, 1] - off_ref[t0, 1]
            for w in solve_widths:
                if w > 0:
                    yield wsq == w, pltpu.make_async_copy(
                        diag_ref.at[pl.ds(off_ref[t0, 0], w)],
                        dbuf.at[sl, pl.ds(0, w)], dsem.at[sl])
            for w in upd_widths:
                if w > 0:
                    yield wuq == w, pltpu.make_async_copy(
                        tiles_ref.at[pl.ds(off_ref[t0, 1], w)],
                        tbuf.at[sl, pl.ds(0, w)], tsem.at[sl])

        @pl.when(jnp.logical_and(p == 0, seg_ref[1] > 0))
        def _():  # warm-up: this launch's first step has no predecessor
            for pred, cp in _step_copies(0, 0):
                pl.when(pred)(cp.start)

        @pl.when(p + 1 < seg_ref[1])
        def _():  # prefetch the next step into the other slot while computing
            for pred, cp in _step_copies(p + 1, jax.lax.rem(p + 1, 2)):
                pl.when(pred)(cp.start)

    @pl.when(p < seg_ref[1])
    def _():
        if stream:  # this step's slices must have landed before compute
            for pred, cp in _step_copies(p, slot):
                pl.when(pred)(cp.wait)

        t_lo = stp_ref[s]
        # streamed scratch holds the whole step slice; level t's entries sit
        # at (off[t] - base) within it
        base_s = off_ref[t_lo, 0]
        base_u = off_ref[t_lo, 1]

        def micro(t, carry):
            # --- solve level t's owned rows (dynamic trip = bucket width) ---
            o_s = off_ref[t, 0]

            def solve_one(i, c):
                r = sr_ref[o_s + i]

                @pl.when(r >= 0)
                def _():
                    L = dbuf[slot, o_s - base_s + i] if stream else diag_ref[r]
                    rhs = b_ref[r] - acc_ref[r]
                    if split_delta:
                        # earlier levels of this merged step accumulated local
                        # contributions into delta (not yet psum-folded into
                        # acc) — intra-step dependencies read them here. For
                        # an unmerged step delta is exactly +0.0: bit-inert.
                        rhs = rhs - delta_ref[r]
                    x_ref[r] = (_solve_tile_panel(L, rhs) if multi
                                else _solve_tile(L, rhs))

                return c

            jax.lax.fori_loop(0, wid_ref[t, 0], solve_one, 0)

            # --- owned-tile updates sourced at level t ---
            o_u = off_ref[t, 1]

            def upd_one(j, c):
                tid = ut_ref[o_u + j]
                # keep the MXU product a standalone dot on materialized
                # operands: letting XLA fuse the gathers or the accumulate
                # into the dot changes its reduction codegen by 1 ulp vs the
                # batched per-op kernels, breaking switch-executor
                # bit-exactness
                tile, xv = jax.lax.optimization_barrier(
                    (tbuf[slot, o_u - base_u + j] if stream else tiles_ref[tid],
                     x_ref[tcol_ref[tid]])
                )
                prod = jax.lax.optimization_barrier(
                    jnp.dot(tile, xv, preferred_element_type=tile.dtype)
                )
                rd = trow_ref[tid]
                delta_ref[rd] = delta_ref[rd] + prod
                return c

            jax.lax.fori_loop(0, wid_ref[t, 1], upd_one, 0)
            return carry

        # run the step's levels in order inside this one grid program — the
        # sequential rowsweep makes intra-step dependencies legal
        jax.lax.fori_loop(t_lo, stp_ref[s + 1], micro, 0)


@functools.partial(
    jax.jit,
    static_argnames=("grid", "split_delta", "interpret", "stream",
                     "solve_widths", "upd_widths"),
)
def superstep_call(
    seg: jax.Array,  # (2,) int32 [first_step, n_active_steps]
    off: jax.Array,  # (T, 3) int32 level offsets into the flats
    wid: jax.Array,  # (T, 3) int32 level bucket widths
    sr: jax.Array,  # (S,) int32 flat solve rows, pad -1
    ut: jax.Array,  # (U,) int32 flat tile slots, pad ML
    trow: jax.Array,  # (ML+1,) int32
    tcol: jax.Array,  # (ML+1,) int32
    diag: jax.Array,  # (nb+1, B, B) resident; (S, B, B) schedule-ordered streamed
    tiles: jax.Array,  # (ML+1, B, B) resident; (U, B, B) schedule-ordered streamed
    b_pad: jax.Array,  # (nb+1, B) or (nb+1, B, R)
    acc: jax.Array,
    x: jax.Array,
    delta: jax.Array | None = None,
    stp: jax.Array | None = None,  # (n_steps+1,) int32 superstep level offsets
    *,
    grid: int,
    split_delta: bool = False,
    interpret: bool = False,
    stream: bool = False,
    solve_widths: tuple = (),
    upd_widths: tuple = (),
):
    """One fused launch executing ``grid`` supersteps starting at ``seg[0]``.

    ``stp`` is the superstep→level offset table; ``None`` means the identity
    (one level per superstep — the plain levelset schedule). Returns the
    updated ``(acc, x)`` carry, or ``(acc, delta, x)`` when ``split_delta``
    (the unified executor's not-yet-exchanged contributions accumulate in
    ``delta``; solves read ``acc + delta`` so later levels of a merged
    superstep see the earlier levels' local contributions).

    With ``stream=True`` the ``diag``/``tiles`` operands are the
    *schedule-ordered* stores (``core.solver.streamed_stores``): they stay in
    ``ANY``/HBM and each superstep's contiguous slice is double-buffered into
    VMEM scratch sized by the max width in ``solve_widths`` / ``upd_widths``
    (the static ladder of distinct per-step summed widths).
    """
    multi = b_pad.ndim == 3
    assert (delta is not None) == split_delta
    if off.shape[0] == 0:
        # empty schedule (0-level plan): every program is inert, but the
        # kernel still traces reads of the level tables — give them one
        # zero row (and a two-entry zero step table) so those
        # (never-executed) reads stay in bounds
        off = jnp.zeros((1, 3), jnp.int32)
        wid = jnp.zeros((1, 3), jnp.int32)
        stp = jnp.zeros((2,), jnp.int32)
    if stp is None:
        stp = jnp.arange(off.shape[0] + 1, dtype=jnp.int32)
    carry_in = (acc, delta, x) if split_delta else (acc, x)
    n_carry = len(carry_in)

    def vec_spec(a):
        zeros = (0,) * a.ndim
        return pl.BlockSpec(a.shape, lambda p, *refs: zeros)

    scratch_shapes = []
    if stream:
        B = diag.shape[-1]
        # the streaming contract: VMEM scratch scales with the widest level
        # slice (double-buffered), never with the total store size
        dshape, tshape = stream_scratch_shapes(solve_widths, upd_widths, B)
        scratch_shapes = [
            pltpu.VMEM(dshape, diag.dtype),
            pltpu.VMEM(tshape, tiles.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ]
        LAST_STREAM_ALLOC.update(
            diag_buf=dshape, tile_buf=tshape,
            diag_store=tuple(diag.shape), tile_store=tuple(tiles.shape),
        )
        store_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
        in_specs = [store_spec, store_spec] + [vec_spec(a) for a in (b_pad, *carry_in)]
    else:
        in_specs = [vec_spec(a) for a in (diag, tiles, b_pad, *carry_in)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=N_PREFETCH,
        grid=(grid,),
        in_specs=in_specs,
        out_specs=[vec_spec(a) for a in carry_in],
        scratch_shapes=scratch_shapes,
    )
    # The carries are deliberately NOT donated via input_output_aliases:
    # callers init them from one zeroed array that XLA may CSE into a single
    # buffer, and two must-alias outputs sharing one operand buffer would let
    # x_ref writes clobber acc_ref on hardware. Program 0's explicit copy-in
    # already pays the one copy per launch that donation would have saved.
    kernel = functools.partial(
        _superstep_kernel, multi=multi, split_delta=split_delta,
        stream=stream, solve_widths=solve_widths, upd_widths=upd_widths,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in carry_in),
        interpret=interpret,
    )(
        seg.astype(jnp.int32), off.astype(jnp.int32), wid.astype(jnp.int32),
        stp.astype(jnp.int32), sr.astype(jnp.int32), ut.astype(jnp.int32),
        trow.astype(jnp.int32), tcol.astype(jnp.int32), diag, tiles, b_pad,
        *carry_in,
    )
    return out
