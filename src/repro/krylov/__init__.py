"""Krylov + preconditioner subsystem: distributed SpTRSV as the hot path of
real iterative solves (paper §I motivation)."""
from repro.krylov.api import (
    IC0Preconditioner,
    ILU0Preconditioner,
    make_ic0_preconditioner,
    make_ilu0_preconditioner,
    solve_cg,
    solve_ic0_pcg,
    solve_ilu0_bicgstab,
)
from repro.krylov.bicgstab import bicgstab
from repro.krylov.cg import KrylovResult, pcg
from repro.krylov.precond import (
    ic0,
    ilu0,
    matvec_lower,
    spd_lower_from_triangular,
    symmetric_full_csr,
)
from repro.krylov.spmv import DistributedSpMV
