"""Front door of the Krylov subsystem — a client of the session API.

``solve_ic0_pcg(A, b, ...)`` takes the lower-triangular half of a symmetric
matrix (the repo's SPD convention) and runs the paper's amortized regime
through one :class:`repro.api.SpTRSVContext`: the pattern is **analysed
once** (block structure + partition + schedules), the IC(0) factor is
**factorized** into that same analysis as a numeric refresh (zero-fill means
the factor shares the matrix pattern exactly), and the forward/backward
triangular sweeps are context **solves** on cached compiled executors — the
L^T sweep is a lazy transpose extension of the same handle, not a second
analysis. Every returned result carries the live context/handles in
``result.info`` so callers (and tests) can audit analysis and invocation
counts.

Preconditioners are durable objects: :class:`IC0Preconditioner` /
:class:`ILU0Preconditioner` support ``refresh(new_matrix)`` — re-running the
numeric factorization on new values of the SAME pattern and re-arming the
compiled executors in place, the piece refactorization workflows previously
faked by rebuilding plans from scratch.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import compat
from repro.api import PlanOptions, SpTRSVContext, as_options
from repro.core.solver import AXIS, SolverConfig
from repro.krylov.bicgstab import bicgstab
from repro.krylov.cg import KrylovResult, pcg
from repro.krylov.precond import ic0, ilu0, symmetric_full_csr, upper_as_reversed_lower
from repro.krylov.spmv import DistributedSpMV
from repro.sparse.matrix import CSR


def _default_mesh(mesh: jax.sharding.Mesh | None) -> jax.sharding.Mesh:
    return mesh if mesh is not None else compat.make_mesh((1,), (AXIS,))


def _context(mesh, config, context) -> SpTRSVContext:
    if context is not None:
        return context
    return SpTRSVContext(mesh=_default_mesh(mesh), options=as_options(config))


class IC0Preconditioner:
    """``M^{-1} r = L^-T L^-1 r`` with IC(0) ``L`` on ``a_lower``'s pattern.

    Both sweeps run through the context's cached executors on ONE analysis —
    the factor handle is tagged ``"ic0"``, so it shares the pattern's
    symbolic analysis with the matrix itself but holds the factor's values
    independently. ``refresh(a_lower_new)`` refactorizes new values on the
    same pattern and re-arms the executors without re-partitioning or
    recompiling.
    """

    TAG = "ic0"

    def __init__(self, ctx: SpTRSVContext, a_lower: CSR):
        self.ctx = ctx
        self.factor = ic0(a_lower)
        self.handle = ctx.factorize(self.factor, tag=self.TAG)

    def refresh(self, a_lower: CSR) -> "IC0Preconditioner":
        self.factor = ic0(a_lower)
        self.ctx.factorize(self.factor, self.handle)
        return self

    def __call__(self, r: np.ndarray) -> np.ndarray:
        y = self.ctx.solve(self.handle, r)
        return self.ctx.solve(self.handle, y, transpose=True)


class ILU0Preconditioner:
    """``M^{-1} r = U^-1 L^-1 r`` with ILU(0) factors of a full CSR.

    The unit-lower factor lives on the strict-lower + diagonal pattern and
    shares that pattern's symbolic analysis (tag ``"ilu0-L"``); the U sweep
    runs as a transpose solve of the reversed ``U^T`` under tag ``"ilu0-U"``
    — on a symmetric pattern that too shares the SAME analysis (``U^T`` has
    L's pattern), so the whole L/U pair costs one partition.
    """

    def __init__(self, ctx: SpTRSVContext, a_full: CSR):
        self.ctx = ctx
        self._lower_handle = None
        self._upper_handle = None
        self._factorize(a_full)

    def _factorize(self, a_full: CSR) -> None:
        self.lower, self.upper = ilu0(a_full)
        # after the first factorization, pass the handles explicitly so a
        # pattern change raises instead of silently re-analysing
        self._lower_handle = self.ctx.factorize(
            self.lower, self._lower_handle, tag="ilu0-L")
        self._upper_handle = self.ctx.factorize(
            upper_as_reversed_lower(self.upper), self._upper_handle, tag="ilu0-U")

    def refresh(self, a_full: CSR) -> "ILU0Preconditioner":
        self._factorize(a_full)
        return self

    def __call__(self, r: np.ndarray) -> np.ndarray:
        y = self.ctx.solve(self._lower_handle, r)
        return self.ctx.solve(self._upper_handle, y, transpose=True)


def make_ic0_preconditioner(
    a_lower: CSR, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig | PlanOptions | None = None, part=None,
    context: SpTRSVContext | None = None,
) -> tuple:
    """IC(0)-factorize and wire the solve pair ``M^{-1} r = L^-T L^-1 r``.

    Returns ``(psolve, handles)``; ``psolve`` is an :class:`IC0Preconditioner`
    (callable, refreshable). ``handles`` keeps the legacy keys (``factor``,
    ``forward``, ``backward`` executors with ``n_solves`` audit counters) plus
    ``context``/``handle``/``preconditioner``. ``part`` is accepted for
    backward compatibility but superseded: partition reuse now happens through
    the context's pattern cache.
    """
    del part  # superseded by the context's single analysis per pattern
    ctx = _context(mesh, config, context)
    pre = IC0Preconditioner(ctx, a_lower)
    return pre, {
        "factor": pre.factor,
        "forward": ctx.executor(pre.handle),
        "backward": ctx.executor(pre.handle, transpose=True),
        "context": ctx, "handle": pre.handle, "preconditioner": pre,
    }


def make_ilu0_preconditioner(
    a_full: CSR, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig | PlanOptions | None = None, part=None,
    context: SpTRSVContext | None = None,
) -> tuple:
    """ILU(0)-factorize a full CSR and wire ``M^{-1} r = U^-1 L^-1 r``."""
    del part  # superseded by the context's single analysis per pattern
    ctx = _context(mesh, config, context)
    pre = ILU0Preconditioner(ctx, a_full)
    return pre, {
        "lower": pre.lower, "upper": pre.upper,
        "forward": ctx.executor(pre._lower_handle),
        "backward": ctx.executor(pre._upper_handle, transpose=True),
        "context": ctx, "preconditioner": pre,
    }


def solve_cg(
    a_lower: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig | PlanOptions | None = None, tol: float = 1e-8,
    maxiter: int = 2000, context: SpTRSVContext | None = None,
) -> KrylovResult:
    """Unpreconditioned CG baseline (distributed SpMV, no triangular solves)."""
    ctx = _context(mesh, config, context)
    spmv = DistributedSpMV(ctx.plan(ctx.analyse(a_lower)), ctx.mesh)
    res = pcg(spmv.matvec, b, tol=tol, maxiter=maxiter)
    res.info.update(spmv=spmv, context=ctx)
    return res


def solve_ic0_pcg(
    a_lower: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig | PlanOptions | None = None, tol: float = 1e-8,
    maxiter: int = 2000, context: SpTRSVContext | None = None,
) -> KrylovResult:
    """PCG with an IC(0) preconditioner — the paper's amortized regime.

    Exactly ONE analysis happens for ``a_lower``'s pattern: the SpMV reads
    the analysis plan with A's values, then the IC(0) factor is numerically
    refreshed into the same handle and both triangular sweeps (forward and
    the lazy transpose extension) solve against it every iteration. ``b`` may
    be ``(n,)`` or an ``(n, R)`` panel.
    """
    ctx = _context(mesh, config, context)
    # the matrix handle (untagged) keeps A's values for the SpMV; the factor
    # lives on a tagged handle sharing the same single symbolic analysis
    spmv = DistributedSpMV(ctx.plan(ctx.analyse(a_lower)), ctx.mesh)
    psolve, handles = make_ic0_preconditioner(a_lower, context=ctx)
    res = pcg(spmv.matvec, b, psolve=psolve, tol=tol, maxiter=maxiter)
    res.info.update(spmv=spmv, **handles)
    return res


def solve_ilu0_bicgstab(
    a_lower: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig | PlanOptions | None = None, tol: float = 1e-8,
    maxiter: int = 2000, context: SpTRSVContext | None = None,
) -> KrylovResult:
    """BiCGStab with an ILU(0) preconditioner built from the full symmetric
    expansion of ``a_lower``. The unit-lower factor shares ``a_lower``'s
    pattern (and therefore its analysis); only the reversed-U pattern adds a
    second analysis."""
    ctx = _context(mesh, config, context)
    spmv = DistributedSpMV(ctx.plan(ctx.analyse(a_lower)), ctx.mesh)
    psolve, handles = make_ilu0_preconditioner(
        symmetric_full_csr(a_lower), context=ctx
    )
    res = bicgstab(spmv.matvec, b, psolve=psolve, tol=tol, maxiter=maxiter)
    res.info.update(spmv=spmv, **handles)
    return res
