"""Front door of the Krylov subsystem, mirroring :func:`repro.core.sptrsv`.

``solve_ic0_pcg(A, b, mesh=..., config=...)`` takes the lower-triangular half
of a symmetric matrix (the repo's SPD convention), factorizes it in place of
pattern, compiles THREE distributed executables once — the SpMV and the
forward/backward triangular solves — and then iterates with zero
re-compilation: the paper's amortized regime, where the solver is invoked
hundreds of times per run. Every returned result carries the live handles in
``result.info`` so callers (and tests) can audit invocation counts.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import compat
from repro.core.solver import AXIS, DistributedSolver, SolverConfig, build_plan
from repro.krylov.bicgstab import bicgstab
from repro.krylov.cg import KrylovResult, pcg
from repro.krylov.precond import ic0, ilu0, symmetric_full_csr, upper_as_reversed_lower
from repro.krylov.spmv import DistributedSpMV
from repro.sparse.matrix import CSR


def _default_mesh(mesh: jax.sharding.Mesh | None) -> jax.sharding.Mesh:
    return mesh if mesh is not None else compat.make_mesh((1,), (AXIS,))


def make_ic0_preconditioner(
    a_lower: CSR, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig = SolverConfig(), part=None,
) -> tuple:
    """IC(0)-factorize and compile the solve pair ``M^{-1} r = L^-T L^-1 r``.

    Returns ``(psolve, handles)`` where both the ``L`` (forward) and ``L^T``
    (backward/transpose) sweeps run through :class:`DistributedSolver`.
    ``part`` reuses a partition built for ``a_lower``'s pattern (zero fill-in
    means the factor shares it exactly).
    """
    mesh = _default_mesh(mesh)
    D = int(mesh.devices.size)
    factor = ic0(a_lower)
    forward = DistributedSolver(build_plan(factor, D, config, part=part), mesh)
    backward = DistributedSolver(build_plan(factor, D, config, transpose=True), mesh)

    def psolve(r: np.ndarray) -> np.ndarray:
        return backward.solve(forward.solve(r))

    return psolve, {"factor": factor, "forward": forward, "backward": backward}


def make_ilu0_preconditioner(
    a_full: CSR, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig = SolverConfig(), part=None,
) -> tuple:
    """ILU(0)-factorize a full CSR and compile ``M^{-1} r = U^-1 L^-1 r``."""
    mesh = _default_mesh(mesh)
    D = int(mesh.devices.size)
    lower, upper = ilu0(a_full)
    forward = DistributedSolver(build_plan(lower, D, config, part=part), mesh)
    backward = DistributedSolver(
        build_plan(upper_as_reversed_lower(upper), D, config, transpose=True), mesh
    )

    def psolve(r: np.ndarray) -> np.ndarray:
        return backward.solve(forward.solve(r))

    return psolve, {"lower": lower, "upper": upper,
                    "forward": forward, "backward": backward}


def solve_cg(
    a_lower: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig = SolverConfig(), tol: float = 1e-8, maxiter: int = 2000,
) -> KrylovResult:
    """Unpreconditioned CG baseline (distributed SpMV, no triangular solves)."""
    mesh = _default_mesh(mesh)
    spmv = DistributedSpMV(build_plan(a_lower, int(mesh.devices.size), config), mesh)
    res = pcg(spmv.matvec, b, tol=tol, maxiter=maxiter)
    res.info.update(spmv=spmv)
    return res


def solve_ic0_pcg(
    a_lower: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig = SolverConfig(), tol: float = 1e-8, maxiter: int = 2000,
) -> KrylovResult:
    """PCG with an IC(0) preconditioner — both triangular sweeps are
    distributed SpTRSV solves on one compiled plan each, reused every
    iteration. ``b`` may be ``(n,)`` or an ``(n, R)`` panel."""
    mesh = _default_mesh(mesh)
    plan_a = build_plan(a_lower, int(mesh.devices.size), config)
    spmv = DistributedSpMV(plan_a, mesh)
    # zero fill-in: the IC(0) factor shares a_lower's pattern, so the matrix
    # partition is reused for the forward sweep instead of re-analysed
    psolve, handles = make_ic0_preconditioner(a_lower, mesh=mesh, config=config,
                                              part=plan_a.part)
    res = pcg(spmv.matvec, b, psolve=psolve, tol=tol, maxiter=maxiter)
    res.info.update(spmv=spmv, **handles)
    return res


def solve_ilu0_bicgstab(
    a_lower: CSR, b: np.ndarray, *, mesh: jax.sharding.Mesh | None = None,
    config: SolverConfig = SolverConfig(), tol: float = 1e-8, maxiter: int = 2000,
) -> KrylovResult:
    """BiCGStab with an ILU(0) preconditioner built from the full symmetric
    expansion of ``a_lower`` (L and U sweeps are distinct compiled solves;
    two preconditioner applications per iteration)."""
    mesh = _default_mesh(mesh)
    plan_a = build_plan(a_lower, int(mesh.devices.size), config)
    spmv = DistributedSpMV(plan_a, mesh)
    # ILU(0)'s unit-lower factor also lives on a_lower's pattern (strict lower
    # of the symmetric expansion + diagonal) -> same partition applies
    psolve, handles = make_ilu0_preconditioner(
        symmetric_full_csr(a_lower), mesh=mesh, config=config, part=plan_a.part
    )
    res = bicgstab(spmv.matvec, b, psolve=psolve, tol=tol, maxiter=maxiter)
    res.info.update(spmv=spmv, **handles)
    return res
