"""Matrix-free preconditioned BiCGStab (host driver).

Right-preconditioned van der Vorst recurrence: the preconditioner application
``M^{-1} v`` is the L/U pair of compiled distributed triangular solves, invoked
twice per iteration — double the SpTRSV pressure of PCG, which is exactly why
the paper's amortized solve cost dominates these workloads. Panels ``(n, R)``
run column-lockstep like :func:`repro.krylov.cg.pcg`.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.krylov.cg import KrylovResult, _col_dot, _norm, _safe_div


def bicgstab(
    matvec: Callable[[np.ndarray], np.ndarray],
    b: np.ndarray,
    *,
    psolve: Callable[[np.ndarray], np.ndarray] | None = None,
    tol: float = 1e-8,
    maxiter: int = 1000,
    x0: np.ndarray | None = None,
) -> KrylovResult:
    """Solve ``A x = b`` (A square, possibly nonsymmetric) per RHS column."""
    b = np.asarray(b, np.float64)
    x = np.zeros_like(b) if x0 is None else np.asarray(x0, np.float64).copy()
    r = b - np.asarray(matvec(x), np.float64) if x0 is not None else b.copy()
    r_hat = r.copy()  # shadow residual
    bnorm = np.maximum(_norm(b), np.finfo(np.float64).tiny)
    rho = alpha = omega = np.ones(b.shape[1:] or ())
    v = p = np.zeros_like(b)
    history = [float(np.max(_norm(r) / bnorm))]
    n_iters = 0
    for _ in range(maxiter):
        rho_new = _col_dot(r_hat, r)
        beta = _safe_div(rho_new * alpha, rho * omega)
        rho = rho_new
        p = r + beta * (p - omega * v)
        ph = np.asarray(psolve(p), np.float64) if psolve else p
        v = np.asarray(matvec(ph), np.float64)
        alpha = _safe_div(rho, _col_dot(r_hat, v))
        s = r - alpha * v
        sh = np.asarray(psolve(s), np.float64) if psolve else s
        t = np.asarray(matvec(sh), np.float64)
        omega = _safe_div(_col_dot(t, s), _col_dot(t, t))
        x = x + alpha * ph + omega * sh
        r = s - omega * t
        n_iters += 1
        relres = _norm(r) / bnorm
        history.append(float(np.max(relres)))
        if np.all(relres <= tol):
            return KrylovResult(x=x, n_iters=n_iters, relres=relres,
                                converged=True, history=history)
    return KrylovResult(x=x, n_iters=n_iters, relres=_norm(r) / bnorm,
                        converged=False, history=history)
