"""Matrix-free preconditioned conjugate gradients (host driver).

The driver is deliberately dumb numpy glue: every flop that matters happens in
the compiled distributed matvec and the pair of compiled distributed triangular
solves passed in as callables. Supports a single RHS ``(n,)`` or a panel
``(n, R)`` — the panel runs R independent CG recurrences in lockstep (all
inner products are per-column), feeding the solver/SpMV multi-RHS paths so one
compiled solve serves the whole batch per iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass
class KrylovResult:
    x: np.ndarray  # (n,) or (n, R)
    n_iters: int
    relres: np.ndarray  # final relative residual(s), shape () or (R,)
    converged: bool
    history: list  # max-over-RHS relative residual per iteration
    info: dict = dataclasses.field(default_factory=dict)


def _col_dot(u: np.ndarray, v: np.ndarray) -> np.ndarray:
    return np.sum(u * v, axis=0)


def _norm(v: np.ndarray) -> np.ndarray:
    return np.sqrt(_col_dot(v, v))


def _safe_div(num: np.ndarray, den: np.ndarray) -> np.ndarray:
    """num/den with 0 where den == 0 (per-column Krylov breakdown guard)."""
    return np.where(den != 0.0, num / np.where(den == 0.0, 1.0, den), 0.0)


def pcg(
    matvec: Callable[[np.ndarray], np.ndarray],
    b: np.ndarray,
    *,
    psolve: Callable[[np.ndarray], np.ndarray] | None = None,
    tol: float = 1e-8,
    maxiter: int = 1000,
    x0: np.ndarray | None = None,
) -> KrylovResult:
    """Solve SPD ``A x = b`` to ``||r|| <= tol * ||b||`` per RHS column."""
    b = np.asarray(b, np.float64)
    x = np.zeros_like(b) if x0 is None else np.asarray(x0, np.float64).copy()
    r = b - np.asarray(matvec(x), np.float64) if x0 is not None else b.copy()
    bnorm = np.maximum(_norm(b), np.finfo(np.float64).tiny)
    z = np.asarray(psolve(r), np.float64) if psolve else r.copy()
    p = z.copy()
    rz = _col_dot(r, z)
    history = [float(np.max(_norm(r) / bnorm))]
    n_iters = 0
    for _ in range(maxiter):
        ap = np.asarray(matvec(p), np.float64)
        pap = _col_dot(p, ap)
        alpha = _safe_div(rz, pap)
        x = x + alpha * p
        r = r - alpha * ap
        n_iters += 1
        relres = _norm(r) / bnorm
        history.append(float(np.max(relres)))
        if np.all(relres <= tol):
            return KrylovResult(x=x, n_iters=n_iters, relres=relres,
                                converged=True, history=history)
        z = np.asarray(psolve(r), np.float64) if psolve else r
        rz_new = _col_dot(r, z)
        beta = _safe_div(rz_new, rz)
        rz = rz_new
        p = z + beta * p
    return KrylovResult(x=x, n_iters=n_iters, relres=_norm(r) / bnorm,
                        converged=False, history=history)
