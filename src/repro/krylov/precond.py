"""Numeric IC(0)/ILU(0) factorization on an existing sparsity (paper §I).

The paper's whole case for fast SpTRSV is that it is the inner kernel of
preconditioner *application*; these host-side factorizations produce the
triangular factors whose solves the :class:`~repro.core.solver.DistributedSolver`
then executes hundreds of times per Krylov run. Zero fill-in: both factors
reuse the input pattern exactly, so one block analysis/partition/compile is
valid for the factor whenever it was valid for the matrix.

Conventions (matching :mod:`repro.sparse.matrix`): a symmetric (SPD) matrix is
represented by its lower-triangular half including the diagonal, col indices
ascending per row with the diagonal entry last.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.matrix import CSR, csr_transpose, to_scipy


def spd_lower_from_triangular(tri: CSR) -> CSR:
    """Lower half of a strictly diagonally dominant SPD matrix on ``tri``'s
    pattern: off-diagonal values are kept, the diagonal is rebuilt as
    ``1 + sum_j |A_ij| (j != i)`` over the *symmetrized* row — dominance of a
    symmetric matrix guarantees positive definiteness, which IC(0) needs."""
    n = tri.n
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(tri.row_ptr))
    cols = tri.col_idx.astype(np.int64)
    off = rows != cols
    o_rows, o_cols, o_vals = rows[off], cols[off], tri.val[off].astype(np.float64)
    dom = np.zeros(n)
    np.add.at(dom, o_rows, np.abs(o_vals))
    np.add.at(dom, o_cols, np.abs(o_vals))  # the mirrored upper entries
    diag = 1.0 + dom
    all_rows = np.concatenate([o_rows, np.arange(n)])
    all_cols = np.concatenate([o_cols, np.arange(n)])
    all_vals = np.concatenate([o_vals, diag])
    order = np.lexsort((all_cols, all_rows))
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(all_rows, minlength=n), out=row_ptr[1:])
    return CSR(n=n, row_ptr=row_ptr, col_idx=all_cols[order].astype(np.int32),
               val=all_vals[order])


def symmetric_full_csr(a_lower: CSR) -> CSR:
    """Full CSR of the symmetric matrix whose lower half is ``a_lower``."""
    low = to_scipy(a_lower).tocsr()
    d = low.diagonal()
    full = (low + low.T).tolil()
    full.setdiag(d)
    full = full.tocsr()
    full.sort_indices()
    return CSR(n=a_lower.n, row_ptr=full.indptr.astype(np.int64),
               col_idx=full.indices.astype(np.int32), val=full.data.astype(np.float64))


def matvec_lower(a_lower: CSR, v: np.ndarray) -> np.ndarray:
    """Host oracle: ``A v`` for symmetric A given its lower half (any RHS shape)."""
    import scipy.sparse as sp

    low = to_scipy(a_lower).tocsr()
    strict = low - sp.diags(low.diagonal())
    return low @ v + strict.T @ v


def ic0(a_lower: CSR) -> CSR:
    """Zero-fill incomplete Cholesky ``A ~= L L^T`` on ``a_lower``'s pattern.

    Up-looking row algorithm: entries are computed in row-major order, dropped
    outside the input pattern (that *is* the IC(0) approximation), and a small
    positive floor guards the pivot against indefinite breakdown (Manteuffel's
    classic failure mode for barely-SPD inputs).
    """
    n, rp, ci = a_lower.n, a_lower.row_ptr, a_lower.col_idx
    lvals = np.zeros(a_lower.nnz)
    # dense work row: zero outside the current row's pattern, so pattern
    # intersection in the inner dot is free (missing entries contribute 0)
    work = np.zeros(n)
    for i in range(n):
        s, e = int(rp[i]), int(rp[i + 1])
        cols = ci[s:e]
        assert cols[-1] == i, "rows must end at the diagonal"
        work[cols] = a_lower.val[s:e]
        for t in range(s, e - 1):
            j = int(ci[t])
            js, je = int(rp[j]), int(rp[j + 1])
            # L[i,j] = (A[i,j] - <row i prefix, row j of L>) / L[j,j]
            dot = np.dot(lvals[js:je - 1], work[ci[js:je - 1]])
            work[j] = (work[j] - dot) / lvals[je - 1]
        head = work[cols[:-1]]
        d = work[i] - np.dot(head, head)
        work[i] = np.sqrt(max(d, 1e-12))
        lvals[s:e] = work[cols]
        work[cols] = 0.0
    return CSR(n=n, row_ptr=rp.copy(), col_idx=ci.copy(), val=lvals)


def ilu0(a: CSR) -> tuple[CSR, CSR]:
    """Zero-fill ILU ``A ~= L U`` of a *full* square CSR (diagonal present).

    IKJ variant: returns unit-lower ``L`` (strictly-lower entries plus an
    explicit unit diagonal, so the triangular solver can consume it directly)
    and upper ``U`` including the diagonal.
    """
    n, rp, ci = a.n, a.row_ptr, a.col_idx
    v = a.val.astype(np.float64).copy()
    diag_ptr = np.empty(n, dtype=np.int64)
    for i in range(n):
        row = ci[rp[i]:rp[i + 1]]
        pos = np.searchsorted(row, i)
        assert pos < row.shape[0] and row[pos] == i, f"missing diagonal in row {i}"
        diag_ptr[i] = rp[i] + pos
    slot = np.full(n, -1, dtype=np.int64)  # column -> nnz slot of the current row
    for i in range(n):
        s, e = int(rp[i]), int(rp[i + 1])
        slot[ci[s:e]] = np.arange(s, e)
        for t in range(s, int(diag_ptr[i])):
            k = int(ci[t])
            # pivot row k < i completed earlier, so its diagonal has already
            # been breakdown-clamped below — never 0 here
            v[t] /= v[diag_ptr[k]]
            # eliminate with row k's upper part, dropped to row i's pattern
            for u in range(int(diag_ptr[k]) + 1, int(rp[k + 1])):
                p = slot[ci[u]]
                if p >= 0:
                    v[p] -= v[t] * v[u]
        slot[ci[s:e]] = -1
        if v[diag_ptr[i]] == 0.0:
            # breakdown guard, written back into U: the diagonal is final once
            # this row's elimination completes, and both later eliminations and
            # the U-triangular solve divide by it
            v[diag_ptr[i]] = 1e-12

    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(rp))
    cols = ci.astype(np.int64)
    lm = rows > cols
    um = rows <= cols
    l_rows = np.concatenate([rows[lm], np.arange(n)])
    l_cols = np.concatenate([cols[lm], np.arange(n)])
    l_vals = np.concatenate([v[lm], np.ones(n)])
    order = np.lexsort((l_cols, l_rows))
    l_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(l_rows, minlength=n), out=l_ptr[1:])
    lower = CSR(n=n, row_ptr=l_ptr, col_idx=l_cols[order].astype(np.int32),
                val=l_vals[order])
    u_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows[um], minlength=n), out=u_ptr[1:])
    upper = CSR(n=n, row_ptr=u_ptr, col_idx=cols[um].astype(np.int32), val=v[um])
    return lower, upper


def upper_as_reversed_lower(u: CSR) -> CSR:
    """U^T as CSR — the lower-triangular input the transpose-plan path needs to
    execute ``U x = y`` (``build_plan(csr_transpose(u), transpose=True)``)."""
    return csr_transpose(u)
