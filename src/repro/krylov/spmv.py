"""Distributed symmetric SpMV reusing the solver's per-device tile stores.

The Krylov matvec ``y = A v`` runs on exactly the data the SpTRSV plan already
sharded: the plan of A's lower-triangular half owns dense diagonal tiles and
per-device strictly-lower tiles (resident on their column's owner). A device
contributes

* ``D_sym[r] @ v[r]``         for the block rows it owns (symmetrized diagonal
  tiles, counted once via the owner mask),
* ``L[r,c] @ v[c]``           for its resident tiles (scattered to row ``r``),
* ``L[r,c]^T @ v[r]``         the mirrored upper entries (scattered to ``c``),

and one psum combines the partial results — the same read-only communication
model as the solver itself. Multi-RHS panels ``(n, R)`` flow through the same
compiled matvec via the kernel layer's rank dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.blocking import pad_rhs, unpad_x
from repro.core.solver import AXIS, Plan
from repro.kernels import ops


def _symmetrize_diag(diag: np.ndarray) -> np.ndarray:
    """(nb+1,B,B) lower-triangular diagonal tiles -> full symmetric tiles."""
    dvals = np.einsum("kii->ki", diag)
    sym = diag + diag.transpose(0, 2, 1)
    k, b, _ = diag.shape
    sym[:, np.arange(b), np.arange(b)] = dvals
    return sym.astype(np.float32)


def _spmv_device_fn(plan: Plan):
    cfg = plan.config
    nb = plan.bs.nb
    multi = plan.n_devices > 1

    def fn(tiles, tiles_t, trow, tcol, owner_mask, sym_diag, v_pad):
        tiles, tiles_t = tiles[0], tiles_t[0]
        trow, tcol, owner_mask = trow[0], tcol[0], owner_mask[0]
        y = ops.batched_block_gemv(sym_diag, v_pad, backend=cfg.kernel_backend)
        y = y * ops.bcast_trailing(owner_mask, y)  # each diag block counted once
        prods = ops.batched_block_gemv(tiles, v_pad[tcol], backend=cfg.kernel_backend)
        y = y.at[trow].add(prods)  # pad tiles are zero -> pad adds are inert
        mirrored = ops.batched_block_gemv(tiles_t, v_pad[trow], backend=cfg.kernel_backend)
        y = y.at[tcol].add(mirrored)
        if multi:
            y = jax.lax.psum(y, AXIS)
        return y[:nb]

    return fn


class DistributedSpMV:
    """Compiled ``y = A v`` for symmetric A given the plan of its lower half."""

    def __init__(self, plan: Plan, mesh: jax.sharding.Mesh):
        assert not plan.transpose, "SpMV needs the plan of A itself"
        assert mesh.devices.size == plan.n_devices
        self.plan = plan
        self.mesh = mesh
        self.n_matvecs = 0
        nb, D = plan.bs.nb, plan.n_devices
        owner_mask = np.zeros((D, nb + 1), np.float32)
        for d in range(D):
            owner_mask[d, :nb] = (plan.part.owner == d).astype(np.float32)
        self._args = (plan.tiles, plan.tiles.transpose(0, 1, 3, 2).copy(),
                      plan.tile_row, plan.tile_col, owner_mask,
                      _symmetrize_diag(plan.diag))
        sharded, repl = P(AXIS), P()
        mapped = compat.shard_map(
            _spmv_device_fn(plan), mesh=mesh,
            in_specs=(sharded,) * 5 + (repl, repl), out_specs=P(),
        )
        self._jitted = jax.jit(mapped)

    def matvec_blocks(self, v_blocks: jax.Array) -> jax.Array:
        """v_blocks: (nb, B) or (nb, B, R) -> same shape."""
        self.n_matvecs += 1
        v_pad = jnp.concatenate(
            [v_blocks, jnp.zeros((1,) + v_blocks.shape[1:], v_blocks.dtype)]
        )
        return self._jitted(*self._args, v_pad)

    def matvec(self, v: np.ndarray) -> np.ndarray:
        """v: (n,) or (n, R) -> A v, same shape."""
        v_blocks = jnp.asarray(pad_rhs(np.asarray(v, np.float32), self.plan.bs))
        return unpad_x(np.asarray(self.matvec_blocks(v_blocks)), self.plan.bs)
