import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the production step function against
ShapeDtypeStruct inputs (no allocation), compiles it for the 16×16 single-pod
mesh and the 2×16×16 multi-pod mesh, prints ``memory_analysis()`` (proves the
cell fits HBM) and ``cost_analysis()`` (FLOPs/bytes for §Roofline), parses
per-device collective payload bytes out of the partitioned HLO, and writes a
JSON artifact per cell to ``experiments/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all          # every applicable cell
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    BF16_OPT, input_specs, model_flops, train_microbatches,
)
from repro.models.model import forward, loss_fn
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective payload bytes by op kind, from partitioned HLO."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op, _ = m.groups()
        b = _shape_bytes(shape_str)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        out[op] = out.get(op, 0) + b
        counts[op] = counts.get(op, 0) + 1
    out["total"] = sum(out.values())
    return {"bytes": out, "counts": counts}


def build_step(arch: str, shape: str, mesh):
    """Returns (fn, args_tuple_of_SDS, donate) for the cell's step function."""
    spec = input_specs(arch, shape, mesh)
    cfg, cell = spec["cfg"], spec["cell"]
    if cell.step == "train":
        fn = make_train_step(
            cfg, mesh, remat=True, fsdp=True,
            microbatches=train_microbatches(arch),
        )
        args = (spec["params"], spec["opt"], spec["batch"],
                jax.ShapeDtypeStruct((), jnp.int32))
        return fn, args, (0, 1)
    if cell.step == "prefill":
        fn = make_prefill_step(cfg, mesh)
        return fn, (spec["params"], spec["batch"], spec["cache"]), (2,)
    fn = make_decode_step(cfg, mesh)
    args = (spec["params"], spec["batch"], spec["cache"],
            jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args, (2,)


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str, *, verbose=True):
    ok, why = cell_applicable(arch, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json"), "w") as f:
                json.dump(rec, f, indent=1)
        print(f"[dryrun] {arch} × {shape} × {mesh_kind}: skipped ({why})", flush=True)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cell = SHAPES[shape]
    cfg = get_config(arch)
    t0 = time.perf_counter()
    try:
        with compat.set_mesh(mesh):  # ambient mesh: activation constraints resolve
            fn, args, donate = build_step(arch, shape, mesh)
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(mem)
        cost = compat.cost_analysis(compiled)
        print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
        # loop-aware re-analysis: XLA's cost_analysis counts while bodies once;
        # hlo_cost multiplies through known_trip_count (see repro.launch.hlo_cost)
        from repro.launch.hlo_cost import analyze

        hc = analyze(compiled.as_text())
        n_dev = int(mesh.devices.size)
        rec.update(
            status="ok",
            n_devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=float(hc["flops"]),
            bytes_per_device=float(hc["dot_bytes"]),
            collectives={"bytes": hc["collective_bytes"],
                         "counts": hc["collective_counts"]},
            raw_cost={"flops": float(cost.get("flops", -1.0)),
                      "bytes_accessed": float(cost.get("bytes accessed", -1.0))},
            model_flops=model_flops(cfg, cell.seq_len, cell.global_batch, cell.step),
            bf16_opt=cfg.name in BF16_OPT,
            memory={
                k: int(getattr(mem, k))
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "alias_size_in_bytes")
                if hasattr(mem, k)
            },
        )
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        msg = rec["status"]
        if msg == "ok":
            msg += (f" lower {rec['lower_s']}s compile {rec['compile_s']}s "
                    f"flops/dev {rec['flops_per_device']:.3e} "
                    f"coll/dev {rec['collectives']['bytes']['total']:.3e}B")
        elif msg == "error":
            msg += " " + rec["error"]
        print(f"[dryrun] {arch} × {shape} × {mesh_kind}: {msg}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s, m) for a in ARCH_IDS for s in SHAPES for m in meshes]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, m) for m in meshes]
    failed = 0
    for a, s, m in cells:
        rec = run_cell(a, s, m, args.out)
        failed += rec["status"] == "error"
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
