"""Loop-aware cost extraction from optimized (post-SPMD) HLO text.

``Compiled.cost_analysis()`` counts while-loop bodies ONCE regardless of trip
count (verified: a 16-step scanned matmul reports 1 matmul of flops), which
undercounts every scanned model by ~n_layers×. This module re-derives
per-device costs with loop multipliers taken from the ``known_trip_count``
backend_config XLA attaches to canonical counted loops:

1. split the HLO module into computations,
2. build the call graph (while body/condition, fusion ``calls=``,
   ``to_apply=``) with multipliers = products of enclosing trip counts,
3. cost per line: dot flops = 2·|out|·contraction (operand shapes resolved
   from the computation's symbol table), collective payload bytes by op kind,
   dot operand/output bytes as an HBM-traffic proxy.

Elementwise flops are ignored (matmuls dominate every cell here). This is a
deliberate engineering cost model — assumptions documented in EXPERIMENTS.md.
Validated against exact expectations in tests/test_hlo_cost.py (single, deep,
and nested scans; loop-carried collectives).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4,
    "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s+\(.*\{\s*$")
_ASSIGN = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\("
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE.search(shape_str)
    return [int(d) for d in m.group(2).split(",") if d] if m else []


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[str]
    symbols: dict


def parse_computations(hlo: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
        m = _ASSIGN.match(line)
        if m:
            cur.symbols[m.group(1)] = m.group(2)
    return comps, entry


def multipliers(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    """Multiplier per computation = product of enclosing loop trip counts."""
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps or mult.get(name, 0.0) >= m:
            return
        mult[name] = m
        for line in comps[name].lines:
            a = _ASSIGN.match(line)
            op = a.group(3) if a else ""
            if op == "while":
                t = 1
                tm = _TRIP.search(line)
                if tm:
                    t = max(1, int(tm.group(1)))
                for rgx in (_BODY, _COND):
                    mm = rgx.search(line)
                    if mm:
                        visit(mm.group(1), m * t)
            else:
                for callee in _CALLS.findall(line):
                    visit(callee, m)

    visit(entry, 1.0)
    return mult


def _dot_cost(line: str, symbols: dict) -> tuple[float, float]:
    """(flops, traffic bytes) for one dot line."""
    m = _ASSIGN.match(line)
    if not m:
        return 0.0, 0.0
    out_elems, out_bytes = _shape_elems_bytes(m.group(2))
    args_m = re.search(r"\bdot\(([^)]*)\)", line)
    contraction = 1
    in_bytes = 0
    if args_m:
        # operands print as "%name" (new XLA) or "f32[...]{...} %name" (old XLA)
        names = re.findall(r"%([\w\.\-]+)", args_m.group(1)) or [
            a.strip().lstrip("%") for a in args_m.group(1).split(",")
        ]
        for nm in names:
            if nm in symbols:
                in_bytes += _shape_elems_bytes(symbols[nm])[1]
        cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        if cd and names and names[0] in symbols:
            lhs_dims = _dims(symbols[names[0]])
            for d in cd.group(1).split(","):
                if d and int(d) < len(lhs_dims):
                    contraction *= lhs_dims[int(d)]
    return 2.0 * out_elems * max(1, contraction), float(out_bytes + in_bytes)


def analyze(hlo: str) -> dict:
    comps, entry = parse_computations(hlo)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].lines))
    mult = multipliers(comps, entry)

    flops = 0.0
    dot_bytes = 0.0
    coll: dict[str, float] = {}
    coll_counts: dict[str, float] = {}
    for name, comp in comps.items():
        m = mult.get(name)
        if m is None:
            continue  # unreachable from entry
        for line in comp.lines:
            a = _ASSIGN.match(line)
            if not a:
                continue
            op = a.group(3)
            if op == "dot":
                f, by = _dot_cost(line, comp.symbols)
                flops += m * f
                dot_bytes += m * by
            else:
                base = op[: -len("-start")] if op.endswith("-start") else op
                if base in _COLL_OPS and not op.endswith("-done"):
                    _, by = _shape_elems_bytes(a.group(2))
                    coll[base] = coll.get(base, 0.0) + m * by
                    coll_counts[base] = coll_counts.get(base, 0.0) + m
    coll["total"] = sum(coll.values())
    return {
        "flops": flops,
        "dot_bytes": dot_bytes,
        "collective_bytes": coll,
        "collective_counts": coll_counts,
        "n_computations": len(comps),
    }
