"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return compat.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh(n: int | None = None, axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over however many (CPU) devices exist — smoke tests/benches."""
    devs = jax.devices()
    n = n or len(devs)
    if len(axes) == 2:
        model = 1
        shape = (n // model, model)
    else:
        shape = (n,)
    return compat.make_mesh(shape, axes, devices=devs[:n])
