"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + greedy decode on the reduced config (CPU-runnable); the
full configs exercise the same engine through the dry-run decode cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models import init_cache, init_params
from repro.serve.engine import make_decode_step, make_prefill_step


def run(arch: str, *, batch: int = 4, prompt_len: int = 32, new_tokens: int = 16,
        mesh=None, quiet: bool = False):
    cfg = get_reduced(arch)
    mesh = mesh or make_host_mesh()
    with compat.set_mesh(mesh):
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        max_seq = prompt_len + new_tokens
        cache = init_cache(cfg, batch, max_seq)
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
        pf_batch = {"tokens": prompts}
        prefill = make_prefill_step(cfg, mesh, example_params=params,
                                    example_cache=cache, example_batch=pf_batch)
        logits, cache = prefill(params, pf_batch, cache)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)
        dec_batch = {"tokens": next_tok[:, None]}
        decode = make_decode_step(cfg, mesh, example_params=params,
                                  example_cache=cache, example_batch=dec_batch)
        out = [next_tok]
        t0 = time.perf_counter()
        for t in range(new_tokens - 1):
            next_tok, cache = decode(params, {"tokens": next_tok[:, None]},
                                     cache, jnp.int32(prompt_len + t))
            out.append(next_tok)
        dt = time.perf_counter() - t0
        toks = jnp.stack(out, axis=1)
        if not quiet:
            print(f"[serve] {arch}: {toks.shape} tokens in {dt:.2f}s "
                  f"({batch*(new_tokens-1)/max(dt,1e-9):.1f} tok/s)")
        return toks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    run(args.arch, batch=args.batch, prompt_len=args.prompt_len,
        new_tokens=args.new_tokens)


if __name__ == "__main__":
    main()
