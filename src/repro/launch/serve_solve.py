"""SpTRSV serving CLI: ``python -m repro.launch.serve_solve [...]``.

Stands up an in-process :class:`repro.service.SolveEngine` and feeds it a
multi-tenant hot/cold request mix: ``--patterns`` distinct synthetic sparsity
patterns, with ``--hot-fraction`` of all requests landing on pattern 0 (the
"hot" preconditioner every iterative solver hammers) and the rest spread over
the cold tail. Reports the serving-axis numbers — solves/sec at the mix,
coalesce width, plan-store hit rate — rather than single-solve latency.

Run it twice against the same ``--plan-store`` directory to see the point of
the subsystem: the first (cold) run pays one symbolic analysis per pattern
and persists the plans; the second (warm) run serves the same mix with
**zero** symbolic analyses, which ``--assert-warm`` turns into a hard exit
code for CI.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro import compat
from repro.api import PlanOptions, SpTRSVContext  # noqa: F401  (session API)
from repro.obs import trace as obs_trace
from repro.service import SolveEngine
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


def build_patterns(n_patterns: int, n: int, levels: int, seed: int) -> list:
    """Distinct synthetic lower-triangular patterns, sized down the tail so
    the cold patterns are cheap and the hot one dominates the work."""
    mats = []
    for p in range(n_patterns):
        np_ = max(64, n // (1 + p))
        mats.append(suite.random_levelled(np_, max(4, levels // (1 + p)), 4.0,
                                          seed=seed + p))
    return mats


def request_mix(n_requests: int, n_patterns: int, hot_fraction: float,
                seed: int) -> list[int]:
    """Pattern index per request: ``hot_fraction`` on pattern 0, the rest
    uniform over the cold tail, in a shuffled arrival order."""
    rng = np.random.default_rng(seed)
    n_hot = int(round(n_requests * hot_fraction))
    mix = [0] * n_hot
    if n_patterns > 1:
        mix += [1 + int(rng.integers(n_patterns - 1))
                for _ in range(n_requests - n_hot)]
    else:
        mix += [0] * (n_requests - n_hot)
    rng.shuffle(mix)
    return mix


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--patterns", type=int, default=3,
                    help="distinct sparsity patterns in the mix")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--hot-fraction", type=float, default=0.7,
                    help="fraction of requests on pattern 0")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--n", type=int, default=512, help="rows of the hot pattern")
    ap.add_argument("--levels", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="coalesced RHS columns per served panel")
    ap.add_argument("--max-wait-ms", type=float, default=0.0,
                    help="admission window before a partial batch dispatches")
    ap.add_argument("--cache-capacity", type=int, default=None,
                    help="LRU bound on the session's compiled-executor cache")
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--sched", default="levelset",
                    choices=["levelset", "dagpart", "syncfree", "auto"])
    ap.add_argument("--comm", default="zerocopy",
                    choices=["zerocopy", "unified", "auto"])
    ap.add_argument("--kernel", default="default")
    ap.add_argument("--plan-store", default=None, metavar="DIR",
                    help="persistent plan store (cold run populates it; a "
                         "warm run serves with zero symbolic analyses)")
    ap.add_argument("--assert-warm", action="store_true",
                    help="exit non-zero unless the mix was served with ZERO "
                         "symbolic analyses (requires a populated --plan-store)")
    ap.add_argument("--assert-hit-rate", type=float, default=None,
                    metavar="MIN", help="exit non-zero if the plan-store hit "
                    "rate falls below MIN")
    ap.add_argument("--trace", default=os.environ.get(obs_trace.ENV_TRACE),
                    metavar="PATH.jsonl")
    args = ap.parse_args()
    if args.trace:
        obs_trace.configure_tracing(args.trace)

    D = len(jax.devices())
    mesh = compat.make_mesh((D,), ("x",))
    opts = PlanOptions(block_size=args.block_size, sched=args.sched,
                       comm=args.comm, kernel=args.kernel)
    mats = build_patterns(args.patterns, args.n, args.levels, args.seed)
    mix = request_mix(args.requests, args.patterns, args.hot_fraction,
                      args.seed)
    print(f"[serve] D={D} patterns={[m.n for m in mats]} "
          f"requests={args.requests} hot={args.hot_fraction:.0%} "
          f"tenants={args.tenants} max_batch={args.max_batch} "
          f"plan_store={args.plan_store or '-'}")

    engine = SolveEngine(mesh=mesh, options=opts, plan_store=args.plan_store,
                         max_batch=args.max_batch,
                         max_wait_s=args.max_wait_ms / 1e3,
                         cache_capacity=args.cache_capacity)
    rng = np.random.default_rng(args.seed + 1)
    t0 = time.perf_counter()
    tickets = [engine.submit(f"tenant{i % args.tenants}", mats[p],
                             rng.uniform(-1, 1, mats[p].n).astype(np.float32))
               for i, p in enumerate(mix)]
    served = engine.drain()
    wall_s = time.perf_counter() - t0

    # spot-check correctness on a few served tickets against scipy
    for t in tickets[:: max(1, len(tickets) // 8)]:
        x = t.result(timeout=0)
        ref = reference_solve(t.request.matrix, t.request.rhs)
        err = np.abs(x - ref).max() / max(np.abs(ref).max(), 1e-30)
        assert err < 1e-4, f"request {t.request.id}: rel.err {err:.2e}"

    st = engine.stats()
    sess, ps = st["session"], st.get("plan_store", {})
    width = st["coalesced_columns"] / st["batches"] if st["batches"] else 0.0
    lat = sorted(t.latency_s for t in tickets)
    p50, p99 = lat[len(lat) // 2], lat[min(len(lat) - 1, int(len(lat) * .99))]
    print(f"[serve] served {served}/{args.requests} in {wall_s*1e3:.0f}ms: "
          f"{served / wall_s:.0f} req/s via {st['batches']} batches "
          f"({st['solves'] / wall_s:.0f} solves/s, coalesce width {width:.1f}, "
          f"pad {st['pad_columns']} cols)")
    print(f"[serve] latency p50={p50*1e3:.1f}ms p99={p99*1e3:.1f}ms | "
          f"analyses={sess.get('analyses', 0)} "
          f"plan_store_hits={sess.get('plan_store_hits', 0)} "
          f"store hit_rate={ps.get('hit_rate', 0.0):.0%} "
          f"evictions={sess.get('evictions', 0)}")

    tracer = obs_trace.get_tracer()
    if tracer.enabled:
        tracer.write({"type": "metrics",
                      "metrics": engine.registry.snapshot()})
        names = sorted({r["name"] for r in tracer.export()
                        if r.get("type") == "span"})
        print(f"[serve] trace: {len(tracer.export())} records -> "
              f"{tracer.path} (spans: {', '.join(names)})")
        tracer.close()

    if args.assert_warm and sess.get("analyses", 0) != 0:
        print(f"[serve] FAIL: --assert-warm but "
              f"{sess['analyses']} symbolic analyses ran")
        raise SystemExit(2)
    if (args.assert_hit_rate is not None
            and ps.get("hit_rate", 0.0) < args.assert_hit_rate):
        print(f"[serve] FAIL: plan-store hit rate {ps.get('hit_rate', 0.0):.2f} "
              f"< --assert-hit-rate {args.assert_hit_rate}")
        raise SystemExit(2)


if __name__ == "__main__":
    main()
