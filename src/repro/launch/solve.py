"""SpTRSV CLI: ``python -m repro.launch.solve --matrix nlpkkt160 [...]``.

Solves Lx=b for a Table-I-suite matrix (or synthetic parameters) under a
chosen design scenario, verifying against scipy and reporting the paper
metrics + communication volume. Runs through the session API
(:class:`repro.api.SpTRSVContext`); pass ``auto`` for ``--sched``/``--comm``/
``--kernel`` to let the calibrated cost model (plus ``--probe N`` measured
probe solves) pick the execution mode.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro import compat
from repro.api import PlanOptions, SpTRSVContext
from repro.core import cut_stats, metrics
from repro.core import partition as partition_strategies
from repro.core.analysis import level_sets
from repro.kernels import ops
from repro.obs import trace as obs_trace
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="webbase-1M", help="Table-I name or 'random'")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--levels", type=int, default=64)
    ap.add_argument("--comm", default="zerocopy",
                    choices=["zerocopy", "unified", "auto"])
    ap.add_argument("--sched", default="levelset",
                    choices=["levelset", "dagpart", "syncfree", "auto"],
                    help="'dagpart' merges runs of narrow levels into single "
                         "supersteps (fewer launches/exchanges on chain-heavy "
                         "factors); tune with --merge-width/--merge-cost")
    ap.add_argument("--merge-width", type=int, default=64,
                    help="dagpart: per-device row budget of a merged superstep")
    ap.add_argument("--merge-cost", type=float, default=0.0,
                    help="dagpart: busiest-device cost below which a level "
                         "counts as narrow (0 = calibrated threshold)")
    ap.add_argument("--partition", default="taskpool",
                    choices=list(partition_strategies.STRATEGIES))
    ap.add_argument("--tasks-per-device", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--kernel", default="default",
                    choices=["default", "auto"] + list(ops.BACKENDS),
                    help="executor backend: 'fused' = superstep megakernel "
                         "(levelset) / frontier-bucketed (syncfree); "
                         "'fused_streamed' = megakernel with the streaming "
                         "HBM tile store (plain 'fused' auto-streams above "
                         "REPRO_STREAM_VMEM_LIMIT); "
                         "'reference'/'pallas' = lax.switch executor; "
                         "'auto' = cost-model / probe selection")
    ap.add_argument("--probe", type=int, default=0,
                    help="measured probe solves per auto candidate "
                         "(0 = cost-model only)")
    ap.add_argument("--rhs-hint", type=int, default=1,
                    help="expected RHS panel width fed to the partition cost model")
    ap.add_argument("--calibrate-cost", action="store_true",
                    help="calibrate malleable cost weights via hlo_cost")
    ap.add_argument("--verify", nargs="?", const="strict", default=None,
                    choices=["basic", "contracts", "strict"],
                    help="statically verify the plan before solving "
                         "(repro.verify: happens-before + kernel-contract "
                         "lint); bare --verify means 'strict'. Exits non-zero "
                         "on findings.")
    ap.add_argument("--trace", default=os.environ.get(obs_trace.ENV_TRACE),
                    metavar="PATH.jsonl",
                    help="write lifecycle spans + a final metrics snapshot "
                         "to this JSONL file (default: env REPRO_TRACE)")
    ap.add_argument("--plan-store", default=None, metavar="DIR",
                    help="persistent plan store directory: reuse a previously "
                         "saved symbolic analysis for this pattern x options "
                         "(strict-verified on load) and save it when missing")
    args = ap.parse_args()
    if args.trace:
        obs_trace.configure_tracing(args.trace)

    if args.matrix == "random":
        a = suite.random_levelled(args.n, args.levels, 4.0, seed=0)
    else:
        entry = {e.name: e for e in suite.table1_suite(args.scale)}[args.matrix]
        a = entry.build()
    m = metrics(a, level_sets(a))
    print(f"[solve] {args.matrix}: n={m.n} nnz={m.nnz} levels={m.n_levels} "
          f"dependency={m.dependency:.2f} parallelism={m.parallelism:.0f}")

    D = len(jax.devices())
    mesh = compat.make_mesh((D,), ("x",))
    opts = PlanOptions(
        block_size=args.block_size, comm=args.comm, sched=args.sched,
        partition=args.partition, tasks_per_device=args.tasks_per_device,
        kernel=args.kernel, rhs_hint=args.rhs_hint,
        merge_width=args.merge_width, merge_cost=args.merge_cost,
        calibrate_cost=args.calibrate_cost, probe_solves=args.probe,
    )
    store = None
    if args.plan_store:
        from repro.service import PlanStore

        store = PlanStore(args.plan_store)
    ctx = SpTRSVContext(mesh=mesh, options=opts, plan_store=store)
    handle = ctx.analyse(a)
    plan = ctx.plan(handle)
    if args.verify:
        from repro.verify import verify_plan

        report = verify_plan(plan, level=args.verify)
        print(f"[solve] {report.summary()}")
        for f in report.findings:
            print(f"[solve]   {f}")
        if not report.passed:
            raise SystemExit(2)
    cs = cut_stats(plan.bs, plan.part)
    print(f"[solve] D={D} block={plan.bs.B} block-levels={plan.n_levels} "
          f"boundary={cs.boundary_fraction:.0%} comm/solve={plan.comm_bytes_per_solve/1e3:.0f}KB "
          f"level-imbalance={cs.level_imbalance:.2f} "
          f"(cost {cs.level_cost_imbalance:.2f}) buckets={len(plan.buckets)}")
    ds = ctx.dispatch_stats(handle)
    if store is not None:
        ps = store.stats
        print(f"[solve] plan-store: hit={ds['plan_store_hit']} "
              f"(hits={ps.get('hits', 0)} misses={ps.get('misses', 0)} "
              f"rejected={ps.get('rejected', 0)} saves={ps.get('saves', 0)}) "
              f"root={store.root}")
    cfg = handle.config
    backend = ops.executor_backend(cfg.kernel_backend)
    if handle.auto is not None:
        sched, comm, kernel = handle.auto.chosen
        print(f"[solve] auto: sched={sched} comm={comm} kernel={kernel} "
              f"({handle.auto.mode}, probe-overhead "
              f"{handle.auto.probe_overhead_us/1e3:.1f}ms)")
    if cfg.sched in ("levelset", "dagpart"):
        stream_note = (f" dma/solve={ds['stream_dma_bytes']/1e3:.0f}KB"
                       if ds["streamed"] else "")
        merge_note = ""
        if cfg.sched == "dagpart":
            merge_note = (f" supersteps={ds['supersteps']}"
                          f"/{ds['supersteps_levelset']} "
                          f"({ds['superstep_reduction']:.1f}x fewer)")
        print(f"[solve] kernel={backend} "
              f"fused-launches={ds['fused_launches']} "
              f"switch-dispatches={ds['switch_dispatches']} "
              f"exchanges={ds['exchanges']} "
              f"streamed={ds['streamed']} "
              f"vmem={ds['fused_vmem_bytes']/1e6:.2f}MB "
              f"sched-table={ds['schedule_table_bytes']/1e3:.1f}KB"
              f"{stream_note}{merge_note}")
    else:
        print(f"[solve] kernel={backend} "
              f"frontier-caps={plan.frontier_caps}")

    rng = np.random.default_rng(0)
    import time

    b = rng.uniform(-1, 1, a.n)
    x = ctx.solve(handle, b)  # compile
    t0 = time.perf_counter()
    for _ in range(args.repeats):
        x = ctx.solve(handle, b)
    dt = (time.perf_counter() - t0) / args.repeats
    err = np.abs(x - reference_solve(a, b)).max() / np.abs(x).max()
    st = ctx.stats()
    print(f"[solve] {dt*1e3:.2f} ms/solve over {args.repeats} runs, rel.err {err:.2e} "
          f"(cache hit rate {st['cache_hit_rate']:.0%})")
    tracer = obs_trace.get_tracer()
    if tracer.enabled:
        # close the trace with one metrics line: plan-static gauges + the
        # session's runtime counters and per-solve wall-clock histogram
        snap = ctx.metrics_snapshot(handle)
        tracer.write({"type": "metrics", "metrics": snap})
        names = sorted({r["name"] for r in tracer.export() if r.get("type") == "span"})
        print(f"[solve] trace: {len(tracer.export())} records -> {tracer.path} "
              f"(spans: {', '.join(names)})")
        tracer.close()


if __name__ == "__main__":
    main()
