"""ShapeDtypeStruct input builders for every (arch × shape-cell × mesh).

The dry-run lowers abstract shapes only — no allocation. All leaves are
weak-type-correct ShapeDtypeStructs carrying NamedShardings so
``jax.jit(...).lower(...)`` sees the intended production layout.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed.meshutil import dp_axes as _dp_axes
from repro.distributed.sharding import batch_specs, cache_specs, param_specs
from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.train.optim import adamw_init

# archs whose optimizer state is kept in bf16 to fit 16 GB/chip (noted §Dry-run)
BF16_OPT = {"llama4-maverick-400b-a17b", "arctic-480b", "granite-34b"}

# train_4k gradient-accumulation microbatches: bounds per-device activation
# liveness (saved residuals scale with local batch) for the big archs
TRAIN_MICROBATCHES = {
    "llama4-maverick-400b-a17b": 8,
    "arctic-480b": 8,
    "granite-34b": 4,
    "zamba2-7b": 2,
    "yi-6b": 2,
    "seamless-m4t-medium": 1,
}


def train_microbatches(arch: str) -> int:
    """Per-arch default, overridable for §Perf A/B runs."""
    env = os.environ.get("REPRO_MICROBATCHES")
    return int(env) if env else TRAIN_MICROBATCHES.get(arch, 1)


def _sds(tree, mesh, spec_tree):
    def one(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree.map(one, tree, spec_tree, is_leaf=lambda x: hasattr(x, "shape"))


def abstract_params(cfg: ModelConfig, mesh, *, fsdp=True):
    from repro.distributed.sharding import SSM_WEIGHT_NAMES

    params = jax.eval_shape(functools.partial(init_params, cfg), jax.random.key(0))
    fsdp_axes = _dp_axes(mesh) if fsdp else ()
    no_tp = SSM_WEIGHT_NAMES if not cfg.ssm_tp else frozenset()
    specs = param_specs(params, mesh, fsdp_axes=fsdp_axes, no_tp_names=no_tp)
    return _sds(params, mesh, specs), specs


def abstract_opt(cfg: ModelConfig, params_sds, mesh, *, fsdp=True):
    from repro.distributed.sharding import SSM_WEIGHT_NAMES

    state_dtype = jnp.bfloat16 if cfg.name in BF16_OPT else jnp.float32
    opt = jax.eval_shape(functools.partial(adamw_init, state_dtype=state_dtype), params_sds)
    fsdp_axes = _dp_axes(mesh) if fsdp else ()
    no_tp = SSM_WEIGHT_NAMES if not cfg.ssm_tp else frozenset()
    specs = {
        "m": param_specs(opt["m"], mesh, fsdp_axes=fsdp_axes, no_tp_names=no_tp),
        "v": param_specs(opt["v"], mesh, fsdp_axes=fsdp_axes, no_tp_names=no_tp),
        "step": P(),
    }
    return _sds(opt, mesh, specs), specs


def batch_shapes(cfg: ModelConfig, seq_len: int, global_batch: int, step: str) -> dict:
    """Abstract batch for a shape cell (train/prefill need S tokens; decode 1)."""
    S = seq_len if step != "decode" else 1
    b: dict = {}
    if cfg.input_kind == "tokens":
        b["tokens"] = jax.ShapeDtypeStruct((global_batch, S), jnp.int32)
    else:
        b["embeds"] = jax.ShapeDtypeStruct((global_batch, S, cfg.d_model), jnp.float32)
    if step == "train":
        b["labels"] = jax.ShapeDtypeStruct((global_batch, S), jnp.int32)
        if cfg.enc_layers:
            b["enc_embeds"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.enc_seq, cfg.d_model), jnp.float32
            )
    elif step == "prefill" and cfg.enc_layers:
        b["enc_out"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.enc_seq, cfg.d_model), jnp.float32
        )
    return b


def input_specs(arch: str, shape: str, mesh) -> dict:
    """All abstract inputs for one dry-run cell: params (+opt/batch/cache)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    dp = _dp_axes(mesh)
    out: dict = {"cfg": cfg, "cell": cell}
    params_sds, pspecs = abstract_params(cfg, mesh)
    out["params"] = params_sds
    out["param_specs"] = pspecs
    batch = batch_shapes(cfg, cell.seq_len, cell.global_batch, cell.step)
    bspecs = batch_specs(batch, mesh, dp_axes=dp)
    out["batch"] = _sds(batch, mesh, bspecs)
    out["batch_specs"] = bspecs
    if cell.step == "train":
        opt_sds, ospecs = abstract_opt(cfg, params_sds, mesh)
        out["opt"] = opt_sds
        out["opt_specs"] = ospecs
    else:
        cache = jax.eval_shape(
            functools.partial(init_cache, cfg, cell.global_batch, cell.seq_len)
        )
        cspecs = cache_specs(cache, mesh, dp_axes=dp)
        out["cache"] = _sds(cache, mesh, cspecs)
        out["cache_specs"] = cspecs
    return out


def model_flops(cfg: ModelConfig, seq_len: int, global_batch: int, step: str) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (fwd-only), D = tokens."""
    n_active = active_param_count(cfg)
    tokens = global_batch * (seq_len if step != "decode" else 1)
    mult = 6.0 if step == "train" else 2.0
    return mult * n_active * tokens


def active_param_count(cfg: ModelConfig) -> float:
    """Per-token active parameters (MoE counts top_k experts, not all)."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    n_mlp = d * f * (3 if cfg.mlp_gated else 2)
    n_attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv * hd * 2
    per_kind = {
        "A": n_attn + n_mlp, "L": n_attn + n_mlp, "H": n_attn + n_mlp,
        "D": n_attn + n_mlp,
        "C": 2 * n_attn + n_mlp,
        "E": n_attn + cfg.top_k * 3 * d * f + d * cfg.n_experts
        + (3 * d * cfg.moe_dense_ff if cfg.moe_dense_ff else 0),
        "M": 0, "S": 0,
    }
    if cfg.ssm_state:
        di = cfg.d_inner
        per_kind["M"] = d * 2 * di + di * d + di * (-(-d // 16) + 2 * cfg.ssm_state) \
            + (-(-d // 16)) * di
        nh = di // cfg.mamba_headdim
        per_kind["S"] = d * (2 * di + 2 * cfg.ssm_state + nh) + di * d
    total = sum(per_kind[k] for k in cfg.layer_kinds)
    total += sum(per_kind[k] for k in cfg.enc_layer_kinds)
    total += cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return float(total)
