"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Fault-tolerance loop (DESIGN.md §3):
* resume from the last committed checkpoint (``CheckpointManager.latest_step``),
* checkpoint every ``--ckpt-every`` steps with atomic commit,
* per-step wall-time budget -> straggler flag in the heartbeat file,
* step retry: a failed step (device error) reloads the last checkpoint and
  continues — exercised by tests/test_train_loop.py via fault injection,
* elastic: restoring onto a different mesh re-shards automatically.

On this CPU container use ``--reduced`` for a runnable ~seconds/step config;
the full configs are exercised through the dry-run instead.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import compat
from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_cache, init_params, param_count
from repro.train.optim import adamw_init
from repro.train.step import make_train_step


def run(
    arch: str, *, steps: int = 20, reduced: bool = True, global_batch: int = 8,
    seq_len: int = 64, ckpt_dir: str | None = None, ckpt_every: int = 10,
    microbatches: int = 1, step_budget_s: float = 0.0, mesh=None, quiet: bool = False,
    peak_lr: float = 3e-4,
):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    mesh = mesh or make_host_mesh()
    with compat.set_mesh(mesh):  # ambient mesh for activation sharding constraints
        return _run_under_mesh(
            cfg, arch, mesh, steps=steps, global_batch=global_batch,
            seq_len=seq_len, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
            microbatches=microbatches, step_budget_s=step_budget_s,
            quiet=quiet, peak_lr=peak_lr,
        )


def _run_under_mesh(cfg, arch, mesh, *, steps, global_batch, seq_len, ckpt_dir,
                    ckpt_every, microbatches, step_budget_s, quiet, peak_lr):
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    data = SyntheticLM(cfg, global_batch, seq_len)

    step_fn = make_train_step(
        cfg, mesh, microbatches=microbatches, peak_lr=peak_lr,
        example_params=params, example_opt=opt, example_batch=data.batch(0),
        donate=True,
    )

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr is not None and (last := mgr.latest_step()) is not None:
        params, opt, manifest = mgr.restore(last, params, opt)
        start = manifest["step"] + 1
        if not quiet:
            print(f"[train] resumed from step {last}")

    if not quiet:
        print(f"[train] {cfg.name}: {param_count(params):,} params, mesh {dict(mesh.shape)}")
    hb_path = os.path.join(ckpt_dir, "heartbeat.json") if ckpt_dir else None
    losses = []
    for step in range(start, steps):
        t0 = time.perf_counter()
        batch = data.batch(step)
        params, opt, metrics = step_fn(params, opt, batch, np.int32(step))
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        straggler = bool(step_budget_s and dt > step_budget_s)
        if hb_path:
            with open(hb_path, "w") as f:
                json.dump({"step": step, "loss": loss, "sec": dt,
                           "straggler": straggler}, f)
        if not quiet:
            print(f"[train] step {step:4d} loss {loss:.4f} ({dt*1e3:.0f} ms)"
                  + (" STRAGGLER" if straggler else ""))
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step, params, opt, {"arch": arch, "mesh": list(mesh.devices.shape)})
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--full", action="store_true", help="full config (needs a pod)")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()
    mesh = make_production_mesh() if args.production_mesh else None
    run(
        args.arch, steps=args.steps, reduced=not args.full,
        global_batch=args.global_batch, seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        microbatches=args.microbatches, mesh=mesh,
    )


if __name__ == "__main__":
    main()
