"""LM architecture zoo: dense/GQA, MoE, Mamba1/2, hybrid, enc-dec, VLM/audio stubs."""
from repro.models.config import ModelConfig
from repro.models.model import forward, init_params, init_cache, param_count
