"""GQA attention (global / sliding-window / cross) with KV-cache decode.

TP layout: Q heads shard over the model axis when divisible (the sharding
rules leave attention weights FSDP-only otherwise); GQA K/V heads are
**repeated to H at use** so every attention einsum carries a single
head axis that propagates cleanly (the (K, g) split defeats XLA's SPMD
propagation — measured as full activation replication, EXPERIMENTS.md §Perf).
The KV *cache* stays K-headed (memory), repeat happens after the cache read.

Long sequences (S >= FLASH_THRESHOLD) use a flash-style double-chunked
online-softmax (``_flash``): O(S·chunk) live memory instead of O(S²) score
matrices — required for the 32k/500k cells to fit HBM. Training wraps the
inner kv step in ``jax.checkpoint`` so the backward *recomputes* the p-matrix
per chunk pair (otherwise autodiff saves all nq·nk score blocks and the flash
memory win evaporates). Inference uses a ``fori_loop`` with data-dependent
trip count: causally masked kv chunks are skipped as compute, not just values.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rotary, softcap

FLASH_THRESHOLD = 2048
FLASH_CHUNK = 1024


def head_pad_mask(cfg: ModelConfig, dtype=jnp.float32) -> jax.Array | None:
    """1.0 for real Q-head slots, 0.0 for padding. Padding is PER KV GROUP
    (each group of g real heads pads to g_pad) so the GQA repeat keeps every
    real head aligned with its own KV head."""
    H, K = cfg.n_heads, cfg.n_kv
    Hp = max(H, cfg.head_pad_to)
    if Hp == H:
        return None
    assert Hp % K == 0, (Hp, K)
    g, gp = H // K, Hp // K
    return ((jnp.arange(Hp) % gp) < g).astype(dtype)


def init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    Hp = max(H, cfg.head_pad_to)
    assert Hp % K == 0, (Hp, K)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype, (d, Hp, hd)),
        "wk": dense_init(ks[1], d, K * hd, dtype, (d, K, hd)),
        "wv": dense_init(ks[2], d, K * hd, dtype, (d, K, hd)),
        "wo": dense_init(ks[3], H * hd, d, dtype, (Hp, hd, d)),
    }
    mask = head_pad_mask(cfg, dtype)
    if mask is not None:  # zero padded heads: no contribution, zero gradients
        p["wq"] = p["wq"] * mask[None, :, None]
        p["wo"] = p["wo"] * mask[:, None, None]
    return p


def _mask(q_pos, k_pos, *, causal: bool, window: int) -> jax.Array:
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), jnp.bool_)
    if causal:
        m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m = jnp.logical_and(m, k_pos[None, :] > q_pos[:, None] - window)
    return m


def _repeat_kv(k: jax.Array, g: int, *, seq_sharded: bool = False) -> jax.Array:
    """(B, S, K, hd) -> (B, S, K*g, hd). Head-sharded downstream by default;
    ``seq_sharded`` keeps the cache-sequence dim sharded instead (decode-SP)."""
    if g == 1:
        return k
    tags = ("dp", "model", None, None) if seq_sharded else ("dp", None, "model", None)
    return constrain(jnp.repeat(k, g, axis=2), *tags)


def _flash(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, H, hd)  (already repeated to H)
    v: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool,
    window: int,
    chunk: int = FLASH_CHUNK,
    differentiable: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    cq = min(chunk, Sq)
    ck = min(chunk, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, Sk, chunk)
    nq, nk = Sq // cq, Sk // ck
    scale = hd ** -0.5
    kc = k.reshape(B, nk, ck, H, hd)
    vc = v.reshape(B, nk, ck, H, hd)

    def q_chunk_step(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, axis=1)  # (B,cq,H,hd)
        qc = constrain(qc, "dp", None, "model", None)
        q_pos = qi * cq + jnp.arange(cq)

        def kv_step(carry, ki):
            m, l, acc = carry
            kck = jax.lax.dynamic_index_in_dim(kc, ki, 1, keepdims=False)
            vck = jax.lax.dynamic_index_in_dim(vc, ki, 1, keepdims=False)
            kck = constrain(kck, "dp", None, "model", None)
            vck = constrain(vck, "dp", None, "model", None)
            k_pos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bshd,bthd->bhst", qc, kck).astype(jnp.float32) * scale
            if cfg.softcap > 0:
                s = softcap(s, cfg.softcap)
            mask = jnp.ones((cq, ck), jnp.bool_)
            if causal:
                mask = k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask = jnp.logical_and(mask, k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            # fully-masked chunks must add zero mass even while the running
            # max sits at the -1e30 sentinel
            p = jnp.where(mask[None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhst,bthd->bhsd", p.astype(qc.dtype), vck)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd), q.dtype)
        if differentiable:
            # scan all chunks; checkpoint the body so backward RECOMPUTES the
            # p-matrices chunk-by-chunk (flash-backward memory profile)
            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(kv_step), (m0, l0, a0), jnp.arange(nk)
            )
        else:
            if causal:  # data-dependent trip count: skip fully-masked chunks
                hi = qi + 1
                lo = jnp.maximum(0, (qi * cq - window) // ck) if window > 0 else 0
            else:
                hi, lo = nk, 0
            body = lambda ki, carry: kv_step(carry, ki)[0]
            m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return None, out.transpose(0, 2, 1, 3)  # (B,cq,H,hd)

    _, chunks = jax.lax.scan(q_chunk_step, None, jnp.arange(nq))
    # chunks: (nq, B, cq, H, hd) -> (B, Sq, H, hd)
    return chunks.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def attention(
    p: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # (S,) absolute positions of x tokens
    window: int = 0,  # 0 = global
    cache: dict | None = None,  # self: {"k","v","pos"}; cross: {"k","v"}
    kv_source: jax.Array | None = None,  # cross-attention memory (B, S_kv, d)
    causal: bool = True,
    is_cross: bool = False,
) -> tuple[jax.Array, dict | None]:
    B, S, d = x.shape
    K, hd = cfg.n_kv, cfg.hd
    H = p["wq"].shape[1]  # may exceed cfg.n_heads under head padding
    g = H // K
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = constrain(q, "dp", None, "model", None)

    if is_cross:
        if kv_source is not None:  # (pre)fill: compute cross K/V from encoder
            k = jnp.einsum("bsd,dhk->bshk", kv_source, p["wk"])
            v = jnp.einsum("bsd,dhk->bshk", kv_source, p["wv"])
            cache = {"k": k, "v": v} if cache is not None else None
        else:  # decode: use precomputed cross K/V
            k, v = cache["k"], cache["v"]
        k, v = _repeat_kv(k, g), _repeat_kv(v, g)
        mask = jnp.ones((S, k.shape[1]), jnp.bool_)
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
        if cache is not None and S == cache["k"].shape[1]:
            # full prefill: the fresh K/V ARE the cache (positions 0..S-1)
            cache = {"k": k.astype(cache["k"].dtype),
                     "v": v.astype(cache["v"].dtype),
                     "pos": jnp.asarray(S, jnp.int32)}
            mask = _mask(positions, positions, causal=causal, window=window)
        elif cache is not None:
            # decode: write the new k/v at `pos`, attend over the whole cache
            pos = cache["pos"]
            ck_ = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv_ = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            k, v = ck_, cv_
            k_pos = jnp.arange(k.shape[1])
            q_pos = pos + jnp.arange(S)
            mask = k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask = jnp.logical_and(mask, k_pos[None, :] > q_pos[:, None] - window)
            cache = {"k": ck_, "v": cv_, "pos": pos + S}
        else:
            mask = _mask(positions, positions, causal=causal, window=window)
        decode_sp = (cache is not None and k.shape[1] != S
                     and os.environ.get("REPRO_DECODE_SP", "1") == "1")
        k = _repeat_kv(k, g, seq_sharded=decode_sp)
        v = _repeat_kv(v, g, seq_sharded=decode_sp)

    if not is_cross and k.shape[1] == S and S >= FLASH_THRESHOLD:
        # flash path; cache==None means a train/eval call that may be grad'ed
        out = _flash(q, k, v, cfg, causal=causal, window=window,
                     differentiable=cache is None)
    else:
        decode_sp = (not is_cross and cache is not None and k.shape[1] != S
                     and os.environ.get("REPRO_DECODE_SP", "1") == "1")
        if decode_sp:
            # decode-SP: the cache shards its SEQUENCE dim over the model axis
            # (cache_specs) — keep attention sharded over it (distributed
            # softmax: psum of per-shard max/sum + partial p·v) instead of
            # letting SPMD all-gather the f32-repeated cache every layer
            # (measured 521 GB/step on llama4 decode — §Perf hillclimb 2).
            q = constrain(q, "dp", None, None, None)
            k = constrain(k, "dp", "model", None, None)
            v = constrain(v, "dp", "model", None, None)
        scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
        if decode_sp:
            scores = constrain(scores, "dp", None, None, "model")
        scores = scores * (hd ** -0.5)
        if cfg.softcap > 0:
            scores = softcap(scores, cfg.softcap)
        scores = jnp.where(mask[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", w, v)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, cache


def init_cross_cache(p: dict, enc_out: jax.Array) -> dict:
    """Precompute cross-attention K/V from encoder output (prefill-time)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return {"k": k, "v": v}
