"""Unified model configuration covering all 10 assigned architecture families.

The per-layer ``pattern`` string selects block kinds:
  ``A`` global attention + MLP          ``L`` sliding-window attention + MLP
  ``E`` attention + MoE FFN             ``D`` attention + dense MLP (in MoE archs)
  ``M`` Mamba1 block                    ``S`` Mamba2 (SSD) block
  ``H`` shared attention block (one param set reused at every H position — zamba2)
The pattern is cycled to ``n_layers``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp_gated: bool = True  # SwiGLU (llama family) vs plain GELU (granite-style)
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    pattern: str = "A"
    sliding_window: int = 4096
    softcap: float = 0.0  # gemma2 attention logit soft-capping
    final_softcap: float = 0.0  # gemma2 final-logit soft-capping
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    moe_dense_ff: int = 0  # arctic: parallel dense-residual MLP width
    # --- SSM ---
    ssm_state: int = 0
    d_inner_mult: int = 2
    conv_kernel: int = 4
    mamba_headdim: int = 64
    ssm_chunk: int = 256  # chunked-scan chunk length (TPU-friendly SSD blocking)
    # TP for SSM layers. False = fully data-parallel mamba blocks (batch over
    # pod×data×model, weights FSDP-gathered at use): trades a per-layer-pass
    # weight all-gather (~p bytes) for the Megatron activation all-reduce
    # (~B·S·d bytes) — a large win when activations >> per-layer params
    # (§Perf hillclimb 1).
    ssm_tp: bool = True
    # --- encoder (enc-dec archs only) ---
    enc_layers: int = 0
    enc_pattern: str = "A"
    enc_seq: int = 0  # encoder input length for dry-run specs
    # --- input modality ---
    input_kind: str = "tokens"  # tokens | embeddings (audio frames / vision patches)
    tie_embeddings: bool = True
    # Pad Q heads up to this count with zero-weight heads (exact: padded heads
    # have zero wo rows, so they contribute nothing and receive no gradient).
    # Restores head-sharded attention TP for archs whose head count doesn't
    # divide the model axis (llama4: 40->48) — §Perf hillclimb 3. 0 = off.
    head_pad_to: int = 0
    # --- numerics ---
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "bfloat16"
    # --- long-context applicability (sub-quadratic attention available?) ---
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 128 (MXU lane + model-axis shardability).

        Pad logits are masked to -inf in the loss and sampling, so semantics
        are exact; only the embedding/head allocation grows.
        """
        return -(-self.vocab // 128) * 128

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        p = (self.pattern * (self.n_layers // len(self.pattern) + 1))[: self.n_layers]
        return tuple(p)

    @property
    def enc_layer_kinds(self) -> tuple[str, ...]:
        p = (self.enc_pattern * (self.enc_layers // len(self.enc_pattern) + 1))
        return tuple(p[: self.enc_layers])

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    def segments(self, kinds: tuple[str, ...] | None = None) -> list[tuple[str, int]]:
        """Group consecutive identical layer kinds into scan segments."""
        kinds = kinds if kinds is not None else self.layer_kinds
        segs: list[tuple[str, int]] = []
        for k in kinds:
            if segs and segs[-1][0] == k:
                segs[-1] = (k, segs[-1][1] + 1)
            else:
                segs.append((k, 1))
        return segs
