"""Shared neural-net primitives (pytree params, functional apply)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_init(key, shape, scale, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype) * scale


def dense_init(key, d_in, d_out, dtype, shape=None):
    shape = shape or (d_in, d_out)
    return uniform_init(key, shape, d_in ** -0.5, dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """RoPE. x: (..., S, H, hd); positions: (..., S) broadcastable."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (.., S, 1, half)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def vocab_pad_mask(logits: jax.Array, valid_vocab: int) -> jax.Array:
    """-inf the padded vocab tail so pad ids never receive probability mass."""
    vp = logits.shape[-1]
    if vp == valid_vocab:
        return logits
    keep = jnp.arange(vp) < valid_vocab
    return jnp.where(keep, logits, -1e30)


def cross_entropy(
    logits: jax.Array, labels: jax.Array, final_cap: float = 0.0,
    valid_vocab: int | None = None,
) -> jax.Array:
    """Mean token cross-entropy; logits promoted to f32 for the reduction."""
    logits = logits.astype(jnp.float32)
    if final_cap > 0:
        logits = softcap(logits, final_cap)
    if valid_vocab is not None:
        logits = vocab_pad_mask(logits, valid_vocab)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
