"""Dense feed-forward blocks: SwiGLU (llama family) and plain GELU (granite)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mlp(key, d: int, f: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], d, f, dtype), "w2": dense_init(ks[1], f, d, dtype)}
    if gated:
        p["w3"] = dense_init(ks[2], d, f, dtype)
    return p


def mlp(p: dict, x: jax.Array, gated: bool) -> jax.Array:
    h = x @ p["w1"]
    if gated:
        h = jax.nn.silu(h) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"]
