"""Model assembly: pattern-driven stage plan, scan-over-periods execution.

The layer stack is compiled (at trace time) into **stages**:
* a ``scan`` stage covers ``n`` repetitions of the config's pattern period —
  parameters are stacked on a leading period axis and executed with
  ``lax.scan`` (bounded HLO size for 88-layer × 512-device lowering);
* a ``block`` stage is a single layer (pattern remainders, shared blocks).

Shared blocks (zamba2's ``H``) keep ONE parameter set, closed over the scan
body, while their KV caches remain per-position (stacked).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models.attention import attention, init_attn
from repro.models.config import ModelConfig
from repro.models.layers import cross_entropy, dense_init, embed_lookup, rms_norm
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import init_mamba1, init_mamba2, mamba1, mamba2

ATTN_KINDS = set("ALEDCH")


@dataclasses.dataclass(frozen=True)
class StageSpec:
    type: str  # "scan" | "block"
    pattern: str  # kinds within one period (scan) or single kind (block)
    n: int  # number of periods (scan) or 1


def build_stage_plan(pattern: str, kinds: tuple[str, ...]) -> list[StageSpec]:
    period = pattern if len(set(pattern)) > 1 else (kinds[0] if kinds else "A")
    plan: list[StageSpec] = []
    n_layers = len(kinds)
    if len(period) > 1:
        n_periods = n_layers // len(period)
        if n_periods > 0:
            plan.append(StageSpec("scan", period, n_periods))
        for k in kinds[n_periods * len(period):]:
            plan.append(StageSpec("block", k, 1))
    else:
        plan.append(StageSpec("scan", period[0], n_layers))
    # merge: a scan with a single period is just blocks
    out: list[StageSpec] = []
    for s in plan:
        if s.type == "scan" and s.n == 1:
            out.extend(StageSpec("block", k, 1) for k in s.pattern)
        else:
            out.append(s)
    return out


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    if kind in ("M",):
        return {"ln": jnp.zeros((d,), dtype), "mix": init_mamba1(ks[0], cfg, dtype)}
    if kind in ("S",):
        return {"ln": jnp.zeros((d,), dtype), "mix": init_mamba2(ks[0], cfg, dtype)}
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "attn": init_attn(ks[0], cfg, dtype),
        "ln2": jnp.zeros((d,), dtype),
    }
    if kind == "E":
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_gated, dtype)
    if kind == "C":
        p["lnx"] = jnp.zeros((d,), dtype)
        p["xattn"] = init_attn(ks[2], cfg, dtype)
    return p


def _init_stage(key, spec: StageSpec, cfg: ModelConfig, dtype) -> dict:
    if spec.type == "block":
        return {"block": _init_block(key, spec.pattern, cfg, dtype)}
    slots: dict = {}
    shared: dict = {}
    keys = jax.random.split(key, len(spec.pattern) + 1)
    for j, kind in enumerate(spec.pattern):
        if kind == "H":  # one shared parameter set for all periods
            shared[str(j)] = _init_block(keys[j], kind, cfg, dtype)
        else:
            init_one = lambda k: _init_block(k, kind, cfg, dtype)
            slots[str(j)] = jax.vmap(init_one)(jax.random.split(keys[j], spec.n))
    return {"slots": slots, "shared": shared}


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params: dict = {}
    if cfg.input_kind == "tokens" or cfg.vocab:
        params["embed"] = dense_init(
            ks[0], cfg.padded_vocab, cfg.d_model, dtype, (cfg.padded_vocab, cfg.d_model)
        )
    plan = build_stage_plan(cfg.pattern, cfg.layer_kinds)
    skeys = jax.random.split(ks[1], len(plan))
    params["stages"] = [_init_stage(skeys[i], s, cfg, dtype) for i, s in enumerate(plan)]
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dtype)
    if cfg.enc_layers:
        enc_plan = build_stage_plan(cfg.enc_pattern, cfg.enc_layer_kinds)
        ekeys = jax.random.split(ks[3], len(enc_plan))
        params["encoder"] = {
            "stages": [_init_stage(ekeys[i], s, cfg, dtype) for i, s in enumerate(enc_plan)],
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int, dtype):
    if kind == "M":
        return {
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
            "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        }
    if kind == "S":
        nh = cfg.d_inner // cfg.mamba_headdim
        return {
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
            "conv_bc": jnp.zeros((batch, cfg.conv_kernel - 1, 2 * cfg.ssm_state), dtype),
            "h": jnp.zeros((batch, nh, cfg.ssm_state, cfg.mamba_headdim), jnp.float32),
        }
    c = {
        "attn": {
            "k": jnp.zeros((batch, max_seq, cfg.n_kv, cfg.hd), dtype),
            "v": jnp.zeros((batch, max_seq, cfg.n_kv, cfg.hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    }
    if kind == "C":
        c["cross"] = {
            "k": jnp.zeros((batch, cfg.enc_seq or max_seq, cfg.n_kv, cfg.hd), dtype),
            "v": jnp.zeros((batch, cfg.enc_seq or max_seq, cfg.n_kv, cfg.hd), dtype),
        }
    return c


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> list:
    dtype = jnp.dtype(cfg.dtype)
    plan = build_stage_plan(cfg.pattern, cfg.layer_kinds)
    caches = []
    for spec in plan:
        if spec.type == "block":
            caches.append({"block": _block_cache(spec.pattern, cfg, batch, max_seq, dtype)})
        else:
            slots = {
                str(j): jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (spec.n,) + x.shape),
                    _block_cache(kind, cfg, batch, max_seq, dtype),
                )
                for j, kind in enumerate(spec.pattern)
            }
            caches.append({"slots": slots})
    return caches


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_block(
    kind: str, p: dict, x: jax.Array, cfg: ModelConfig, *, positions,
    cache=None, enc_out=None, causal=True,
):
    x = constrain(x, "dp", None, None)  # residual stream: batch over DP axes
    if kind in ("M", "S"):
        fn = mamba1 if kind == "M" else mamba2
        out, new_c = fn(p["mix"], rms_norm(x, p["ln"], cfg.norm_eps), cfg, cache)
        return x + out.astype(x.dtype), new_c
    new_cache = dict(cache) if cache is not None else None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    window = cfg.sliding_window if kind == "L" else 0
    a, c_attn = attention(
        p["attn"], h, cfg, positions=positions, window=window,
        cache=cache["attn"] if cache else None, causal=causal,
    )
    if new_cache is not None:
        new_cache["attn"] = c_attn
    x = x + a.astype(x.dtype)
    if kind == "C" and (enc_out is not None or cache is not None):
        h = rms_norm(x, p["lnx"], cfg.norm_eps)
        xc = cache["cross"] if cache else None
        a, nxc = attention(p["xattn"], h, cfg, positions=positions, cache=xc,
                           kv_source=enc_out, is_cross=True)
        if new_cache is not None:
            new_cache["cross"] = nxc
        x = x + a.astype(x.dtype)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    f = moe_ffn(p["moe"], h, cfg) if kind == "E" else mlp(p["mlp"], h, cfg.mlp_gated)
    return x + f.astype(x.dtype), new_cache


def _apply_stages(
    stages_params: list, plan: list[StageSpec], x: jax.Array, cfg: ModelConfig, *,
    positions, caches=None, enc_out=None, causal=True, remat=False,
):
    new_caches = []
    for i, spec in enumerate(plan):
        sp = stages_params[i]
        cache_i = caches[i] if caches is not None else None
        if spec.type == "block":
            blk = functools.partial(
                _apply_block, spec.pattern, cfg=cfg, positions=positions,
                enc_out=enc_out, causal=causal,
            )
            if remat:
                blk = jax.checkpoint(blk)
            x, nc = blk(sp["block"], x, cache=cache_i["block"] if cache_i else None)
            new_caches.append({"block": nc})
        else:
            shared = sp["shared"]

            def period_body(h, xs):
                slot_params, slot_caches = xs
                new_slot_caches = {}
                for j, kind in enumerate(spec.pattern):
                    p_j = shared[str(j)] if kind == "H" else slot_params[str(j)]
                    c_j = slot_caches.get(str(j)) if slot_caches else None
                    h, nc_j = _apply_block(
                        kind, p_j, h, cfg, positions=positions,
                        cache=c_j, enc_out=enc_out, causal=causal,
                    )
                    if nc_j is not None:
                        new_slot_caches[str(j)] = nc_j
                return h, new_slot_caches

            body = jax.checkpoint(period_body) if remat else period_body
            slot_caches = cache_i["slots"] if cache_i else None
            x, ncs = jax.lax.scan(body, x, (sp["slots"], slot_caches))
            new_caches.append({"slots": ncs})
    return x, (new_caches if caches is not None else None)


def encode(params: dict, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """Run the (bidirectional) encoder over stub modality embeddings."""
    plan = build_stage_plan(cfg.enc_pattern, cfg.enc_layer_kinds)
    pos = jnp.arange(enc_embeds.shape[1])
    x, _ = _apply_stages(
        params["encoder"]["stages"], plan, enc_embeds.astype(jnp.dtype(cfg.dtype)),
        cfg, positions=pos, causal=False,
    )
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array | None = None,  # (B, S) int32
    embeds: jax.Array | None = None,  # (B, S, d) modality-stub inputs
    *,
    cache: list | None = None,
    pos_offset: jax.Array | int = 0,
    enc_out: jax.Array | None = None,
    remat: bool = False,
    last_only: bool = False,
):
    """Returns (logits (B,S,padded_vocab), new_cache). ``last_only`` computes
    the LM head for the final position only (prefill: avoids a (B,S,V) buffer)."""
    if embeds is None:
        embeds = embed_lookup(params["embed"], tokens)
    x = embeds.astype(jnp.dtype(cfg.dtype))
    if enc_out is not None:
        enc_out = enc_out.astype(jnp.dtype(cfg.dtype))
    S = x.shape[1]
    positions = pos_offset + jnp.arange(S)
    plan = build_stage_plan(cfg.pattern, cfg.layer_kinds)
    x, new_cache = _apply_stages(
        params["stages"], plan, x, cfg, positions=positions, caches=cache,
        enc_out=enc_out, causal=True, remat=remat,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, new_cache


def loss_fn(
    params: dict, cfg: ModelConfig, tokens: jax.Array, labels: jax.Array,
    embeds: jax.Array | None = None, enc_embeds: jax.Array | None = None,
    remat: bool = True,
) -> jax.Array:
    enc_out = encode(params, cfg, enc_embeds) if enc_embeds is not None else None
    logits, _ = forward(
        params, cfg, tokens, embeds=embeds, enc_out=enc_out, remat=remat
    )
    return cross_entropy(logits, labels, cfg.final_softcap, valid_vocab=cfg.vocab)
