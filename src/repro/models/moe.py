"""Token-choice top-k MoE with capacity-bounded dispatch (EP-shardable).

Dispatch strategy (DESIGN.md §7): flatten (token, expert-choice) pairs, rank
each pair within its expert by a one-hot cumsum, drop beyond-capacity pairs,
gather into a dense (E, C, d) buffer, run the expert FFNs as stacked einsums
(sharded over the expert axis = expert parallelism), and combine with router
gates. Active-FLOP accounting matches 6·N_active·D — no dense all-expert
compute and no GShard-style quadratic dispatch einsum.

Supports arctic's parallel *dense residual* MLP via ``moe_dense_ff``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.models.mlp import init_mlp, mlp


def _decode_weight_stationary() -> bool:
    """§Perf hillclimb 2 knob (default on; =0 reproduces the baseline)."""
    return os.environ.get("REPRO_MOE_DECODE_WS", "1") == "1"


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w1": dense_init(ks[1], d, f, dtype, (E, d, f)),
        "w2": dense_init(ks[2], f, d, dtype, (E, f, d)),
        "w3": dense_init(ks[3], d, f, dtype, (E, d, f)),
    }
    if cfg.moe_dense_ff:
        p["dense"] = init_mlp(ks[4], d, cfg.moe_dense_ff, True, dtype)
    return p


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    # Decode/small batches use no-drop capacity (exact routing); large token
    # counts use the standard capacity factor with overflow dropping.
    C = N * k if N * k <= 4096 else max(1, int(cfg.capacity_factor * N * k / E))
    xt = constrain(x.reshape(N, d), "dp", None)

    logits = xt.astype(jnp.float32) @ p["router"]  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, k)  # (N, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)  # renormalize over k

    e_flat = choice.reshape(N * k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (N*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, e_flat[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = e_flat * C + jnp.where(keep, pos, 0)

    x_rep = constrain(jnp.repeat(xt, k, axis=0), "dp", None)  # (N*k, d) pairs
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].add(
        jnp.where(keep[:, None], x_rep, 0)
    )
    # expert parallelism: the dispatch buffer lives expert-sharded (all-to-all
    # happens at the scatter above / gather below)
    h = constrain(buf.reshape(E, C, d), "model", None, None)
    a = jnp.einsum("ecd,edf->ecf", h, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", h, p["w3"])
    if N * k <= 4096 and _decode_weight_stationary():
        # decode: keep expert weights fully sharded (E over model, f over the
        # data axes) and compute with f-sharded intermediates — moving ~MBs of
        # activations instead of all-gathering ~GBs of expert weights per
        # token (§Perf hillclimb 2). The w2 contraction over sharded f yields
        # a partial-sum all-reduce of the small (E,C,d) buffer.
        a = constrain(a, "model", None, "dp")
        g = constrain(g, "model", None, "dp")
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(a) * g, p["w2"])
    y = constrain(y, "model", None, None)

    out_pairs = y.reshape(E * C, d)[slot] * (keep * gate.reshape(N * k))[:, None]
    out_pairs = constrain(out_pairs, "dp", None)
    out = out_pairs.reshape(N, k, d).sum(axis=1).reshape(B, S, d)
    if "dense" in p:  # arctic dense-residual path runs in parallel with experts
        out = out + mlp(p["dense"], x, True)
    return out.astype(x.dtype)
