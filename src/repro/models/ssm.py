"""Mamba1 (selective scan) and Mamba2 (SSD) blocks, TPU-adapted.

Both use a **chunked** formulation (scan over chunks of ``cfg.ssm_chunk``
tokens) so the (B, S, d_inner, N) state tensor is never materialized for the
full sequence — per-chunk working sets fit VMEM/HBM budgets at 500k context.
Mamba2 uses the SSD matmul form (intra-chunk attention-like GEMMs + inter-chunk
state GEMMs), which maps the recurrence onto the MXU. Decode is a single-step
state update (O(1) per token — the reason these archs run the ``long_500k``
cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm, uniform_init


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C), w: (K,C), b: (C,)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _conv_step(state: jax.Array, xt: jax.Array, w: jax.Array, b: jax.Array):
    """Single-token conv. state: (B,K-1,C), xt: (B,1,C) -> (y, new_state)."""
    window = jnp.concatenate([state, xt], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y[:, None], window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def _dt_rank(cfg: ModelConfig) -> int:
    return -(-cfg.d_model // 16)


def init_mamba1(key, cfg: ModelConfig, dtype) -> dict:
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.conv_kernel
    R = _dt_rank(cfg)
    ks = jax.random.split(key, 8)
    # x/z projections kept separate (not fused) so each column-shards cleanly
    return {
        "x_in": dense_init(ks[0], d, di, dtype),
        "z_proj": dense_init(ks[5], d, di, dtype),
        "conv_w": uniform_init(ks[1], (K, di), K ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, R + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], R, di, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), (di, N)
        ).astype(jnp.float32),
        "Dskip": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _scan_combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, b1 * a2 + b2


def mamba1(p: dict, u: jax.Array, cfg: ModelConfig, cache: dict | None = None):
    """u: (B,S,d). Returns (out, new_cache)."""
    B, S, d = u.shape
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.conv_kernel
    R = _dt_rank(cfg)
    bt, ct = ("dp", "model") if cfg.ssm_tp else ("dpm", None)
    x = constrain(u @ p["x_in"], bt, None, ct)
    z = constrain(u @ p["z_proj"], bt, None, ct)

    if cache is not None and S == 1:
        xc, conv_state = _conv_step(cache["conv"], x, p["conv_w"], p["conv_b"])
    else:
        xc = _causal_conv(x, p["conv_w"], p["conv_b"])
        conv_state = x[:, -(K - 1):, :] if cache is not None else None
    x = jax.nn.silu(xc)

    dbc = x @ p["x_proj"]
    dt = jax.nn.softplus(dbc[..., :R] @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    Bc = dbc[..., R : R + N].astype(jnp.float32)
    Cc = dbc[..., R + N :].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])  # (di,N)
    xf = x.astype(jnp.float32)

    if cache is not None and S == 1:
        h = cache["h"]  # (B,di,N)
        da = jnp.exp(dt[:, 0, :, None] * A)
        h = da * h + (dt * xf)[:, 0, :, None] * Bc[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
        new_cache = {"conv": conv_state, "h": h}
    else:
        Q = min(cfg.ssm_chunk, S)
        assert S % Q == 0, (S, Q)
        nc = S // Q
        da = jnp.exp(dt[..., None] * A).reshape(B, nc, Q, di, N)
        db = ((dt * xf)[..., None] * Bc[:, :, None, :]).reshape(B, nc, Q, di, N)
        Ccc = Cc.reshape(B, nc, Q, N)

        def chunk_step(h, inputs):
            da_c, db_c, C_c = inputs  # (B,Q,di,N),(B,Q,di,N),(B,Q,N)
            da_c = constrain(da_c, bt, None, ct, None)
            db_c = constrain(db_c, bt, None, ct, None)
            cum_a, h_within = jax.lax.associative_scan(_scan_combine, (da_c, db_c), axis=1)
            h_t = h_within + cum_a * h[:, None]
            y_c = jnp.einsum("bqdn,bqn->bqd", h_t, C_c)
            return h_t[:, -1], y_c

        h0 = cache["h"] if cache is not None else jnp.zeros((B, di, N), jnp.float32)
        hN, y = jax.lax.scan(
            chunk_step, h0,
            (da.transpose(1, 0, 2, 3, 4), db.transpose(1, 0, 2, 3, 4),
             Ccc.transpose(1, 0, 2, 3)),
        )
        y = y.transpose(1, 0, 2, 3).reshape(B, S, di)
        new_cache = {"conv": conv_state, "h": hN} if cache is not None else None

    y = (y + xf * p["Dskip"].astype(jnp.float32)).astype(u.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, new_cache


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.conv_kernel
    nh = di // cfg.mamba_headdim
    ks = jax.random.split(key, 6)
    # projections and convs kept separate (z / x / BC / dt): each piece
    # column-shards cleanly instead of splitting a fused buffer mid-shard
    return {
        "z_proj": dense_init(ks[0], d, di, dtype),
        "x_in": dense_init(ks[3], d, di, dtype),
        "bc_proj": dense_init(ks[4], d, 2 * N, dtype),
        "dtp": dense_init(ks[5], d, nh, dtype),
        "conv_w": uniform_init(ks[1], (K, di), K ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "conv_bc_w": uniform_init(ks[1], (K, 2 * N), K ** -0.5, dtype),
        "conv_bc_b": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),
        "Dskip": jnp.ones((nh,), dtype),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def mamba2(p: dict, u: jax.Array, cfg: ModelConfig, cache: dict | None = None):
    B, S, d = u.shape
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.conv_kernel
    hp = cfg.mamba_headdim
    nh = di // hp
    bt, ct = ("dp", "model") if cfg.ssm_tp else ("dpm", None)
    z = constrain(u @ p["z_proj"], bt, None, ct)
    xr = constrain(u @ p["x_in"], bt, None, ct)
    bc = u @ p["bc_proj"]
    dt = constrain(u @ p["dtp"], bt, None, ct)

    if cache is not None and S == 1:
        x, conv_state = _conv_step(cache["conv"], xr, p["conv_w"], p["conv_b"])
        bc, conv_bc_state = _conv_step(cache["conv_bc"], bc, p["conv_bc_w"], p["conv_bc_b"])
    else:
        conv_state = xr[:, -(K - 1):, :] if cache is not None else None
        conv_bc_state = bc[:, -(K - 1):, :] if cache is not None else None
        x = _causal_conv(xr, p["conv_w"], p["conv_b"])
        bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    x = x.reshape(B, S, nh, hp).astype(jnp.float32)
    x = constrain(x, bt, None, ct, None)
    Bc, Cc = Bc.astype(jnp.float32), Cc.astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    la = dt * A  # (B,S,nh) log-decay per step (negative)
    xdt = x * dt[..., None]  # (B,S,nh,hp)

    if cache is not None and S == 1:
        h = cache["h"]  # (B,nh,N,hp)
        h = jnp.exp(la)[:, 0, :, None, None] * h + jnp.einsum(
            "bn,bhp->bhnp", Bc[:, 0], xdt[:, 0]
        )
        y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0], h)[:, None].reshape(B, 1, di)
        new_cache = {"conv": conv_state, "conv_bc": conv_bc_state, "h": h}
    else:
        Q = min(cfg.ssm_chunk, S)
        assert S % Q == 0, (S, Q)
        nc = S // Q

        def chunk_step(h, inputs):
            la_c, x_c, B_c, C_c = inputs  # (B,Q,nh),(B,Q,nh,hp),(B,Q,N),(B,Q,N)
            la_c = constrain(la_c, bt, None, ct)
            x_c = constrain(x_c, bt, None, ct, None)
            cum = jnp.cumsum(la_c, axis=1)  # (B,Q,nh)
            # intra-chunk: attention-like masked decay matmul (MXU)
            M = jnp.einsum("bqn,bpn->bqp", C_c, B_c)  # (B,Q,Q)
            L = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,q,p,nh)
            tri = jnp.tril(jnp.ones((Q, Q), bool))
            W = jnp.where(tri[None, :, :, None], M[..., None] * L, 0.0)
            y_intra = jnp.einsum("bqph,bphd->bqhd", W, x_c)
            # inter-chunk: contribution of the carried state
            y_inter = jnp.einsum("bqn,bhnd->bqhd", C_c, h) * jnp.exp(cum)[..., None]
            # new carried state
            decay_tail = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,nh)
            h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + jnp.einsum(
                "bpn,bphd->bhnd", B_c, x_c * decay_tail[..., None]
            )
            return h_new, y_intra + y_inter

        h0 = (
            cache["h"] if cache is not None
            else jnp.zeros((B, nh, N, hp), jnp.float32)
        )
        to_chunks = lambda t: t.reshape((B, nc, Q) + t.shape[2:]).swapaxes(0, 1)
        hN, y = jax.lax.scan(
            chunk_step, h0, (to_chunks(la), to_chunks(xdt), to_chunks(Bc), to_chunks(Cc))
        )
        y = y.swapaxes(0, 1).reshape(B, S, nh, hp).reshape(B, S, di)
        new_cache = (
            {"conv": conv_state, "conv_bc": conv_bc_state, "h": hN}
            if cache is not None else None
        )

    y = y + (x * p["Dskip"].astype(jnp.float32)[None, None, :, None]).reshape(B, S, di)
    y = rms_norm((y.astype(u.dtype) * jax.nn.silu(z)), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, new_cache
