"""Unified telemetry: span tracing, metrics registry, calibration feedback.

Three cooperating pieces (ISSUE 6):

* :mod:`repro.obs.trace`       — nested lifecycle spans -> JSONL
  (``REPRO_TRACE=path.jsonl``), aligned with XLA profiles via
  ``jax.named_scope`` annotations baked into the executors.
* :mod:`repro.obs.metrics`     — typed counters/gauges/histograms unifying
  the solver's scattered plan-static and runtime stats behind one
  ``snapshot()``/JSONL sink.
* :mod:`repro.obs.calibration` — measured probe timings persisted per
  (backend, bucket-width signature) and fitted back into
  ``core.costmodel.calibrate_weights`` (``REPRO_CALIBRATION=weights.json``).

All of it is zero-cost when disabled: the null tracer is a shared no-op,
registry writes are a few dict operations, and nothing here ever enters
traced computation — solve results are bit-identical with telemetry on or
off, and toggling it cannot retrace a compiled executor.
"""
from repro.obs.calibration import (
    CalibrationStore,
    calibrated_stream_limit,
    fitted_weights,
    get_store,
    probe_signature,
    set_store,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    record_plan_metrics,
)
from repro.obs.trace import (
    NULL_TRACER,
    Tracer,
    configure_tracing,
    get_tracer,
    trace_to,
)

__all__ = [
    "CalibrationStore", "calibrated_stream_limit", "fitted_weights",
    "get_store", "probe_signature",
    "set_store", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "record_plan_metrics", "NULL_TRACER", "Tracer",
    "configure_tracing", "get_tracer", "trace_to",
]
