"""Wall-clock calibration feedback loop (ISSUE 6 tentpole, part c).

The auto-tuner's probe solves are real wall-clock samples of the cost model's
compute term — this module gives them a durable home and feeds them back into
:func:`repro.core.costmodel.calibrate_weights`, closing the ROADMAP's
"wall-clock calibration feedback loop": a session with ``probe_solves=0``
inherits weights *fitted from earlier measured runs* instead of pure
``hlo_cost`` estimates.

Model
-----
One measured solve of a plan at RHS width R costs, in the block-op model,

    us  ~=  c0  +  c_solve * su  +  c_mem * tu  +  c_flop * tf

where ``(su, tu, tf) = (sum(ws)*R, sum(wu), sum(wu)*R)`` are the plan's
schedule work units (:func:`repro.api.autotune.plan_work_units`) and ``c0``
is a fixed per-solve dispatch overhead — on CPU a few hundred microseconds
that would otherwise be smeared into (and often overwhelm) the marginal
coefficients. The intercept is fitted and discarded: it is identical for
every candidate of a given solve, so it cancels in plan ranking. Each probe
records one sample keyed by ``(backend, B)`` and deduplicated by the plan's
*bucket-width signature* (re-probing the same schedule replaces its sample
rather than double-weighting it). Fitting:

* samples spanning >= 2 distinct R and a full-rank system fit all three
  marginal coefficients directly;
* the common uniform-R case collapses ``tu``/``tf`` into one tile column
  (they are collinear); the fitted total tile cost is split into its mem/flop
  parts by the hlo-calibrated ratio at the samples' mean R — measured totals,
  HLO-shaped split;
* when the sample set mixes schedulers whose work units price differently
  (syncfree counts speculative sweep revisits that levelset never executes),
  the pooled fit can violate the sign guards; the fitter then retries per
  sched group — largest group first — and returns the first trustworthy fit;
* under-determined or ill-conditioned sample sets (< 2 samples, rank-
  deficient regressors, non-positive solve coefficient) return ``None`` and
  the caller falls back to the pure HLO weights — calibration can only
  degrade gracefully, never produce nonsense.

Fitted weights are normalized to ``w_solve = 1`` like the HLO weights they
replace, so they drop into ``block_row_cost`` / ``estimate_plan_cost``
unchanged.

Persistence: ``CalibrationStore(path=...)`` saves after every ``record`` and
loads on construction; env ``REPRO_CALIBRATION=weights.json`` makes the
process-global store durable across sessions (the acceptance path: a probed
run persists, a later ``probe_solves=0`` run picks the weights up).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading

import numpy as np

ENV_CALIBRATION = "REPRO_CALIBRATION"

MIN_SAMPLES = 2  # one sample cannot separate solve from tile cost
COND_LIMIT = 1e8  # reject ill-conditioned fits (near-collinear work units)


def probe_signature(plan, R: int = 1) -> str:
    """Stable id of what a probe measured: sched x comm x backend x block
    size x the plan's bucket-width schedule x RHS width. Same schedule,
    same signature — re-probes replace the sample instead of stacking."""
    from repro.core.solver import level_widths

    cfg = plan.config
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(level_widths(plan)).tobytes())
    head = f"{cfg.sched}/{cfg.comm}/{cfg.kernel_backend or 'default'}"
    return f"{head}/B{plan.bs.B}/R{int(R)}/{h.hexdigest()[:12]}"


class CalibrationStore:
    """Measured (work-units -> wall-clock) samples per (backend, B), with
    least-squares weight fitting and JSON persistence."""

    def __init__(self, path: str | None = None):
        self.path = path
        self._samples: dict[str, dict] = {}  # "backend/B##" -> {sig: sample}
        self._fits: dict[str, tuple | None] = {}
        self._lock = threading.Lock()
        if path and os.path.exists(path):
            self.load(path)

    @staticmethod
    def _key(backend: str, B: int) -> str:
        return f"{backend}/B{int(B)}"

    def record(self, *, backend: str, B: int, signature: str,
               solve_units: float, tile_units: float, tile_flop_units: float,
               R: int, measured_us: float) -> None:
        """Install one measured sample (replacing any prior sample with the
        same signature) and persist when the store has a path."""
        sample = {
            "su": float(solve_units), "tu": float(tile_units),
            "tf": float(tile_flop_units), "R": int(R),
            "us": float(measured_us),
        }
        with self._lock:
            self._samples.setdefault(self._key(backend, B), {})[signature] = sample
            self._fits.pop(self._key(backend, B), None)
        if self.path:
            self.save(self.path)

    def samples(self, backend: str, B: int) -> dict:
        with self._lock:
            return dict(self._samples.get(self._key(backend, B), {}))

    def sample_groups(self) -> dict[str, dict]:
        """Snapshot of every ``"backend/B##" -> {sig: sample}`` group."""
        with self._lock:
            return {k: dict(v) for k, v in self._samples.items()}

    def n_samples(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._samples.values())

    # -- fitting ----------------------------------------------------------

    def fitted_weights(self, B: int, backend: str) -> tuple | None:
        """``(1.0, w_tile_mem, w_tile_flop)`` fitted from this store's
        measured samples for ``(backend, B)``, or ``None`` when the samples
        cannot support a trustworthy fit. Cached per key until new samples
        arrive, so repeat calls return the identical tuple."""
        key = self._key(backend, B)
        with self._lock:
            if key in self._fits:
                return self._fits[key]
            samples = dict(self._samples.get(key, {}))
        fit = _fit_weights(samples, B, backend)
        with self._lock:
            self._fits[key] = fit
        return fit

    # -- persistence ------------------------------------------------------

    def save(self, path: str) -> None:
        with self._lock:
            blob = {"version": 1, "samples": self._samples}
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: concurrent readers see old or new

    def load(self, path: str) -> None:
        with open(path) as f:
            blob = json.load(f)
        if blob.get("version") != 1:
            raise ValueError(f"unknown calibration file version in {path!r}")
        with self._lock:
            self._samples = {k: dict(v) for k, v in blob["samples"].items()}
            self._fits.clear()


def _fit_weights(samples: dict, B: int, backend: str) -> tuple | None:
    """Fit ``{signature: sample}``; pooled first, per-sched groups on guard
    failure (heterogeneous schedulers price a work unit differently)."""
    fit = _fit_sample_set(list(samples.values()), B, backend)
    if fit is not None:
        return fit
    groups: dict[str, list] = {}
    for sig, s in samples.items():
        groups.setdefault(sig.split("/", 1)[0], []).append(s)
    for _, grp in sorted(groups.items(), key=lambda kv: (-len(kv[1]), kv[0])):
        if len(grp) < len(samples):
            fit = _fit_sample_set(grp, B, backend)
            if fit is not None:
                return fit
    return None


def _fit_sample_set(samples: list, B: int, backend: str) -> tuple | None:
    if len(samples) < MIN_SAMPLES:
        return None
    su = np.array([s["su"] for s in samples], dtype=np.float64)
    tu = np.array([s["tu"] for s in samples], dtype=np.float64)
    tf = np.array([s["tf"] for s in samples], dtype=np.float64)
    us = np.array([s["us"] for s in samples], dtype=np.float64)
    if not (np.all(np.isfinite(us)) and np.all(us > 0) and np.all(su > 0)):
        return None

    if len(samples) >= 3 and len({s["R"] for s in samples}) >= 2:
        w = _solve_affine(np.stack([su, tu, tf], axis=1), us)
        if w is not None and w[0] > 0 and w[1] >= 0 and w[2] >= 0:
            return (1.0, float(w[1] / w[0]), float(w[2] / w[0]))

    # uniform-R (or rank-deficient) path: tu and tf are collinear, so fit the
    # total tile coefficient and split it by the HLO-calibrated ratio
    w = _solve_affine(np.stack([su, tu], axis=1), us)
    if w is None or w[0] <= 0 or w[1] < 0:
        return None
    c_tile = float(w[1] / w[0])  # w_tile_mem + w_tile_flop*mean R, w_solve-normed
    r_mean = float(np.mean([s["R"] for s in samples]))
    from repro.core.costmodel import hlo_weights

    _, hm, hf = hlo_weights(B, backend=backend)
    denom = hm + hf * r_mean
    if denom <= 0:
        return (1.0, c_tile, 0.0)  # HLO says tiles are free: keep it all mem-side
    return (1.0, c_tile * hm / denom, c_tile * hf / denom)


def _solve_affine(A: np.ndarray, y: np.ndarray) -> np.ndarray | None:
    """Least squares with an intercept column absorbing the fixed per-solve
    dispatch overhead; the intercept is dropped from the returned vector.
    Falls back to the homogeneous fit when rows cannot support an intercept."""
    ones = np.ones((A.shape[0], 1), dtype=np.float64)
    w = _solve_ls(np.concatenate([ones, A], axis=1), y)
    if w is not None:
        return w[1:]
    return _solve_ls(A, y)


def _solve_ls(A: np.ndarray, y: np.ndarray) -> np.ndarray | None:
    """Least squares with rank/conditioning guards; None when untrustworthy."""
    if A.shape[0] < A.shape[1]:
        return None
    if np.linalg.matrix_rank(A) < A.shape[1]:
        return None
    if np.linalg.cond(A) > COND_LIMIT:
        return None
    w, *_ = np.linalg.lstsq(A, y, rcond=None)
    if not np.all(np.isfinite(w)):
        return None
    return w


# -- streamed/resident crossover --------------------------------------------

STREAM_LIMIT_FLOOR = 1 * 2**20  # never push the crossover below 1 MiB
STREAM_LIMIT_CEIL = 64 * 2**20  # or keep tiles resident above 64 MiB


def _unit_cost(samples: list) -> float | None:
    """Median measured microseconds per schedule work unit (su + tu)."""
    units = np.array([s["su"] + s["tu"] for s in samples], dtype=np.float64)
    us = np.array([s["us"] for s in samples], dtype=np.float64)
    ok = np.isfinite(us) & (us > 0) & (units > 0)
    if not np.any(ok):
        return None
    return float(np.median(us[ok] / units[ok]))


def calibrated_stream_limit(store: CalibrationStore | None = None) -> int | None:
    """Measured streamed/resident VMEM crossover in bytes, or ``None``.

    The auto-tuner's probe solves time the same compacted schedules under
    both the resident (``fused``) and DMA double-buffered
    (``fused_streamed``) executors; their per-work-unit wall-clock ratio is
    a direct platform measurement of what streaming actually costs. When
    streaming is nearly free (ratio ~1) the resident store stops paying for
    its VMEM and the crossover should drop; when the DMA bursts are slow the
    crossover rises. The fixed 8 MiB default
    (:data:`repro.core.solver.DEFAULT_STREAM_VMEM_LIMIT`) is scaled by the
    median ratio across block sizes with samples for *both* executors,
    clamped to ``[1 MiB, 64 MiB]``. Returns ``None`` when no block size has
    paired samples — callers keep the fixed default, so unprobed sessions
    behave exactly as before. Env ``REPRO_STREAM_VMEM_LIMIT`` overrides both
    (handled by :func:`repro.core.solver.stream_vmem_limit`).
    """
    groups = (store or get_store()).sample_groups()
    fused: dict[str, list] = {}
    streamed: dict[str, list] = {}
    for key, sig_map in groups.items():
        backend, _, b_tag = key.partition("/")
        if backend == "fused":
            fused.setdefault(b_tag, []).extend(sig_map.values())
        elif backend == "fused_streamed":
            streamed.setdefault(b_tag, []).extend(sig_map.values())
    ratios = []
    for b_tag in sorted(set(fused) & set(streamed)):
        cf = _unit_cost(fused[b_tag])
        cs = _unit_cost(streamed[b_tag])
        if cf is not None and cs is not None and cf > 0:
            ratios.append(cs / cf)
    if not ratios:
        return None
    from repro.core.solver import DEFAULT_STREAM_VMEM_LIMIT

    lim = DEFAULT_STREAM_VMEM_LIMIT * float(np.median(ratios))
    return int(np.clip(lim, STREAM_LIMIT_FLOOR, STREAM_LIMIT_CEIL))


# -- global store ----------------------------------------------------------

_store: CalibrationStore | None = None


def get_store() -> CalibrationStore:
    """The process-global store; durable when env ``REPRO_CALIBRATION`` names
    a file (loaded on first access, saved after every recorded probe)."""
    global _store
    if _store is None:
        _store = CalibrationStore(path=os.environ.get(ENV_CALIBRATION))
    return _store


def set_store(store: CalibrationStore | None) -> None:
    """Swap the global store (tests; ``None`` re-reads the env on next use)."""
    global _store
    _store = store


def fitted_weights(B: int, backend: str | None = None) -> tuple | None:
    """Global-store fit for the *resolved executor* backend — the thing the
    probes actually measured (``None``/"default" resolves per platform)."""
    from repro.kernels import ops

    return get_store().fitted_weights(B, ops.executor_backend(backend))
