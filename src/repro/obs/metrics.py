"""Typed metrics registry (ISSUE 6 tentpole, part b).

One home for the quantities that used to live in scattered one-off probes —
``dispatch_stats`` / ``cut_stats`` / ``comm_bytes_per_solve`` (plan-static)
and cache hit rates / refresh counts / per-solve wall-clock / probe timings
(runtime). Three instrument types:

* :class:`Counter`   — monotically increasing event count (``inc``),
* :class:`Gauge`     — last-written value (``set``),
* :class:`Histogram` — running count/sum/min/max/last of observations
  (``observe``) — enough for wall-clock distributions without binning.

``snapshot()`` returns a plain JSON-serializable dict and ``dump()`` appends
it as one JSONL line (the same sink format the span tracer uses, so a trace
file can interleave spans and metrics snapshots).

:func:`record_plan_metrics` is the bridge from the solver's plan-static
probes into the registry: it mirrors ``dispatch_stats``/``cut_stats`` and the
communication/DMA/VMEM byte counts under ``plan.*`` gauges, so a snapshot of
a known plan agrees field-for-field with the scattered stats it unifies.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time


class Counter:
    __slots__ = ("value",)

    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, v: int = 1) -> None:
        self.value += v

    def snap(self):
        return self.value


class Gauge:
    __slots__ = ("value",)

    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def snap(self):
        return self.value


class Histogram:
    __slots__ = ("count", "total", "vmin", "vmax", "last")

    kind = "histogram"

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.last = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.last = v

    def snap(self) -> dict:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "last": 0.0}
        return {"count": self.count, "sum": self.total, "min": self.vmin,
                "max": self.vmax, "mean": self.total / self.count,
                "last": self.last}


class MetricsRegistry:
    """Named typed instruments, created on first use.

    Re-requesting a name with a different instrument type is a programming
    error and raises — one name, one meaning, for the life of the registry.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        """``{name: value}`` for every instrument (histograms as summary
        dicts), JSON-serializable, sorted by name."""
        with self._lock:
            return {name: _jsonable(self._metrics[name].snap())
                    for name in sorted(self._metrics)}

    def dump(self, path: str) -> dict:
        """Append one ``{"type": "metrics", ...}`` JSONL line; returns the
        snapshot it wrote."""
        snap = self.snapshot()
        rec = {"type": "metrics", "t_unix_s": time.time(), "metrics": snap}
        with open(path, "a", buffering=1) as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
        return snap

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


def _jsonable(v):
    if isinstance(v, bool):
        return int(v)
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (int, float, str)) or v is None:
        return v
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()  # numpy scalar
    return str(v)


def record_plan_metrics(registry: MetricsRegistry, plan, *, prefix: str = "plan"
                        ) -> MetricsRegistry:
    """Mirror a plan's static probes into ``prefix.*`` gauges.

    Covers exactly the quantities the solver already reports — launch /
    dispatch / exchange counts, the fused memory plan (``streamed``,
    ``fused_vmem_bytes``, ``stream_dma_bytes``), the collective payload
    (``comm_bytes_per_solve``), and the partition's cut/balance statistics
    (``boundary_fraction``, ``level_cost_imbalance``, ...) — so the registry
    snapshot is byte-for-byte reconciled with ``dispatch_stats``/``cut_stats``
    in tests.
    """
    from repro.core.partition import cut_stats
    from repro.core.solver import dispatch_stats

    g = registry.gauge
    for k, v in dispatch_stats(plan).items():
        g(f"{prefix}.{k}").set(_jsonable(v))
    g(f"{prefix}.comm_bytes_per_solve").set(plan.comm_bytes_per_solve)
    g(f"{prefix}.n_levels").set(plan.n_levels)
    g(f"{prefix}.n_devices").set(plan.n_devices)
    g(f"{prefix}.n_buckets").set(len(plan.buckets))
    g(f"{prefix}.n_boundary_rows").set(plan.n_boundary_rows)
    for f in dataclasses.fields(cs := cut_stats(plan.bs, plan.part)):
        g(f"{prefix}.{f.name}").set(_jsonable(getattr(cs, f.name)))
    return registry


# -- global registry -------------------------------------------------------

_global = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (contexts, engines, and benches
    record here unless handed their own)."""
    return _global
