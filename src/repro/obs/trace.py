"""Span tracer for the solver lifecycle (ISSUE 6 tentpole, part a).

The session lifecycle — ``analyse -> partition -> schedule -> factorize ->
solve -> refresh`` — emits *nested spans*: each span records its name, a
monotonically increasing id, its parent span, the wall-clock start offset and
duration, and free-form attributes. Spans wrap **host-side staging only**
(plan construction, executor dispatch, probe loops); they never enter traced
computation, so toggling tracing can neither change solve results nor trigger
a retrace. Alignment with XLA profiles comes from two always-on, zero-cost
channels instead:

* the executors annotate their traced bodies with ``jax.named_scope`` under
  the same ``sptrsv.*`` names (pure HLO metadata, applied unconditionally so
  the compiled program is identical with tracing on or off), and
* enabled spans additionally enter ``jax.profiler.TraceAnnotation`` where the
  jax version provides it, so host spans appear on the profiler timeline
  next to the device rows.

Enable with env ``REPRO_TRACE=path.jsonl`` (picked up on first
:func:`get_tracer` call) or programmatically via :func:`configure_tracing` /
the :func:`trace_to` context manager. Disabled tracing routes through a
shared no-op span object — no allocation, no timestamp reads, no file I/O.

JSONL schema (one JSON object per line, appended so subprocesses can share a
file):

    {"type": "span", "name": "sptrsv.solve", "id": 7, "parent": null,
     "t0_us": 1234.5, "dur_us": 210.0, "attrs": {"R": 1}}
    {"type": "metrics", "t_us": 99.0, "metrics": {...}}   # registry snapshots

Children close before their parents, so a parent's line always appears
*after* all of its children's — readers that need tree order sort by ``id``.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

try:  # host-timeline annotation; optional across jax versions
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - only on exotic jax builds
    _TraceAnnotation = None

ENV_TRACE = "REPRO_TRACE"


class Span:
    """One live span. Use as a context manager; ``set()`` attaches attrs."""

    __slots__ = ("_tracer", "name", "span_id", "parent_id", "t0_ns", "dur_us",
                 "attrs", "_ann")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: int | None, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0_ns = 0
        self.dur_us = 0.0
        self.attrs = attrs
        self._ann = None

    @property
    def enabled(self) -> bool:
        return True

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (e.g. plan shape)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        if _TraceAnnotation is not None:
            self._ann = _TraceAnnotation(self.name)
            self._ann.__enter__()
        self.t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.dur_us = (time.perf_counter_ns() - self.t0_ns) / 1e3
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
            self._ann = None
        self._tracer._finish(self)
        return False


class _NullSpan:
    """Shared do-nothing span: the disabled-tracing fast path."""

    __slots__ = ()
    enabled = False

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class _NullTracer:
    """Disabled tracer: every call is a constant-time no-op."""

    enabled = False
    path = None

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def write(self, record: dict) -> None:
        pass

    def export(self) -> list:
        return []

    def close(self) -> None:
        pass


NULL_TRACER = _NullTracer()


class Tracer:
    """Collects nested spans; optionally appends them to a JSONL file.

    Span ids increase monotonically in *open* order, giving a deterministic
    total order independent of wall-clock resolution. Nesting uses a
    per-thread stack so concurrent host threads cannot corrupt parenting;
    the record list and file writes are lock-protected.
    """

    enabled = True

    def __init__(self, path: str | None = None):
        self.path = path
        self._records: list[dict] = []
        self._next_id = 0
        self._lock = threading.Lock()
        self._local = threading.local()
        self._file = None
        self._t0_ns = time.perf_counter_ns()

    # -- span lifecycle ---------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs) -> Span:
        stack = self._stack()
        with self._lock:
            span_id = self._next_id
            self._next_id += 1
        parent = stack[-1].span_id if stack else None
        s = Span(self, name, span_id, parent, attrs)
        stack.append(s)
        return s

    def _finish(self, span: Span) -> None:
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()
        rec = {
            "type": "span",
            "name": span.name,
            "id": span.span_id,
            "parent": span.parent_id,
            "t0_us": (span.t0_ns - self._t0_ns) / 1e3,
            "dur_us": span.dur_us,
        }
        if span.attrs:
            rec["attrs"] = _jsonable(span.attrs)
        self.write(rec)

    # -- sink -------------------------------------------------------------

    def write(self, record: dict) -> None:
        """Record an arbitrary JSONL line (spans, metrics snapshots, ...)."""
        with self._lock:
            self._records.append(record)
            if self.path is not None:
                if self._file is None:
                    # append + line-buffered: subprocesses can share the file
                    self._file = open(self.path, "a", buffering=1)
                self._file.write(json.dumps(record, sort_keys=True) + "\n")

    def export(self) -> list:
        """All records so far (the in-memory mirror of the JSONL sink)."""
        with self._lock:
            return list(self._records)

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


def _jsonable(attrs: dict) -> dict:
    """Coerce attribute values to JSON-serializable scalars/strings."""
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (bool, int, float, str)) or v is None:
            out[k] = v
        elif hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
            out[k] = v.item()  # numpy scalar
        else:
            out[k] = str(v)
    return out


# -- global tracer ---------------------------------------------------------

_active: Tracer | _NullTracer | None = None


def get_tracer() -> Tracer | _NullTracer:
    """The active tracer. First call honors env ``REPRO_TRACE=path.jsonl``;
    without it, tracing stays a no-op until :func:`configure_tracing`."""
    global _active
    if _active is None:
        path = os.environ.get(ENV_TRACE)
        _active = Tracer(path=path) if path else NULL_TRACER
    return _active


def configure_tracing(path: str | None = None, *, enabled: bool = True
                      ) -> Tracer | _NullTracer:
    """Install a tracer (``path=None`` keeps spans in memory only);
    ``enabled=False`` disables tracing entirely. Returns the new tracer."""
    global _active
    if _active is not None:
        _active.close()
    _active = Tracer(path=path) if enabled else NULL_TRACER
    return _active


@contextlib.contextmanager
def trace_to(path: str | None = None):
    """Temporarily install a tracer (tests, scoped CLI runs); restores the
    previous tracer on exit."""
    global _active
    prev = _active
    tracer = Tracer(path=path)
    _active = tracer
    try:
        yield tracer
    finally:
        tracer.close()
        _active = prev
