from repro.serve.engine import make_decode_step, make_prefill_step
