"""Serving steps: batched prefill and single-token decode with sharded caches.

KV caches shard batch over DP and the cache sequence dim over the model axis
(decode sequence-parallelism); SSM states shard channels over model — see
``repro.distributed.sharding.cache_specs``. Greedy sampling keeps the step
deterministic; the launcher wraps these into a request loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.meshutil import dp_axes as _dp_axes
from repro.distributed.sharding import batch_specs, cache_specs, param_specs
from repro.models.config import ModelConfig
from repro.models.layers import vocab_pad_mask
from repro.models.model import forward
from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer


def _shard(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def make_prefill_step(cfg: ModelConfig, mesh, *, example_params=None,
                      example_cache=None, example_batch=None, fsdp: bool = False):
    dp = _dp_axes(mesh)

    def prefill(params, batch, cache):
        with jax.named_scope("serve.prefill"):
            logits, cache = forward(
                params, cfg, batch.get("tokens"), embeds=batch.get("embeds"),
                cache=cache, pos_offset=0, enc_out=batch.get("enc_out"),
                last_only=True,
            )
        return logits, cache

    if example_params is None:
        return prefill
    pspecs = _shard(mesh, param_specs(example_params, mesh, fsdp_axes=dp if fsdp else ()))
    cspecs = _shard(mesh, cache_specs(example_cache, mesh, dp_axes=dp))
    bspecs = _shard(mesh, batch_specs(example_batch, mesh, dp_axes=dp))
    jitted = jax.jit(
        prefill,
        in_shardings=(pspecs, bspecs, cspecs),
        out_shardings=(_shard(mesh, P(dp if len(dp) > 1 else dp[0], None, None)), cspecs),
        donate_argnums=(2,),
    )

    def stepper(params, batch, cache):
        with get_tracer().span("serve.prefill"):
            get_registry().counter("serve.prefills").inc()
            return jitted(jax.device_put(params, pspecs),
                          jax.device_put(batch, bspecs),
                          jax.device_put(cache, cspecs))

    return stepper


def make_decode_step(cfg: ModelConfig, mesh, *, example_params=None,
                     example_cache=None, example_batch=None, fsdp: bool = False):
    """One token for every sequence in the batch; greedy argmax sampling."""
    dp = _dp_axes(mesh)

    def decode(params, batch, cache, pos):
        with jax.named_scope("serve.decode"):
            logits, cache = forward(
                params, cfg, batch.get("tokens"), embeds=batch.get("embeds"),
                cache=cache, pos_offset=pos,
            )
            logits = vocab_pad_mask(logits[:, -1].astype(jnp.float32), cfg.vocab)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    if example_params is None:
        return decode
    pspecs = _shard(mesh, param_specs(example_params, mesh, fsdp_axes=dp if fsdp else ()))
    cspecs = _shard(mesh, cache_specs(example_cache, mesh, dp_axes=dp))
    bspecs = _shard(mesh, batch_specs(example_batch, mesh, dp_axes=dp))
    jitted = jax.jit(
        decode,
        in_shardings=(pspecs, bspecs, cspecs, NamedSharding(mesh, P())),
        out_shardings=(None, cspecs),
        donate_argnums=(2,),
    )

    def stepper(params, batch, cache, pos):
        with get_tracer().span("serve.decode", pos=int(pos)):
            get_registry().counter("serve.decodes").inc()
            return jitted(jax.device_put(params, pspecs),
                          jax.device_put(batch, bspecs),
                          jax.device_put(cache, cspecs), pos)

    return stepper
