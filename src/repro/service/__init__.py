"""SpTRSV-as-a-service: multi-tenant batched solve engine (ISSUE 9 tentpole).

Three layers over the session API:

* :mod:`repro.service.planstore` — cross-session persistence of the symbolic
  analysis (block structure, partition, compacted schedules, ``step_off``,
  bucket tables) keyed by pattern sha1 x options signature, so short-lived
  workers skip the expensive dependency analysis entirely.
* :mod:`repro.service.queue` — multi-tenant request admission: same-pattern
  RHS vectors coalesce into the multi-RHS ``(k, B, R)`` panels the kernels
  already execute, under a max-wait/max-batch window with per-tenant fairness
  and bounded-queue backpressure.
* :mod:`repro.service.engine` — the serve loop driving one
  :class:`repro.api.SpTRSVContext`: plan-store-backed analyse, in-place value
  refresh on hot patterns, ``service.*`` metrics and ``service.request`` /
  ``service.batch`` tracer spans through :mod:`repro.obs`.
"""
from repro.service.engine import SolveEngine
from repro.service.planstore import PlanStore, options_signature
from repro.service.queue import QueueFull, SolveQueue, SolveRequest, Ticket

__all__ = [
    "PlanStore",
    "QueueFull",
    "SolveEngine",
    "SolveQueue",
    "SolveRequest",
    "Ticket",
    "options_signature",
]
