"""The solve-serving engine loop (ISSUE 9 tentpole, layer 3).

One :class:`SolveEngine` drives one :class:`repro.api.SpTRSVContext` over one
mesh: batches admitted by the :class:`repro.service.queue.SolveQueue` are
analysed through the plan store (cold patterns pay the symbolic analysis
once per *fleet*, not once per process), numeric value changes on a hot
pattern refresh in place via the factorize path (zero re-partition, zero
retrace), and the coalesced ``(n, R)`` panel executes as one compiled
multi-RHS solve.

Telemetry rides through :mod:`repro.obs`: ``service.*`` metrics (queue depth,
coalesce width, plan-store hit rate, per-request/batch latency histograms)
mirror the engine's own counters field-for-field, and every batch/request
emits a ``service.batch`` / ``service.request`` tracer span. The tracer
never enters compiled code, so served results are bit-identical with tracing
on or off.

Drive it synchronously (``step`` / ``drain`` — deterministic, what the tests
and benches use) or as a background thread (``start`` / ``stop`` or the
context manager), which serves tickets while tenants block on
:meth:`repro.service.queue.Ticket.result`.
"""
from __future__ import annotations

import collections
import threading
import time

import numpy as np

from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer
from repro.service.planstore import PlanStore
from repro.service.queue import SolveQueue, Ticket
from repro.sparse.matrix import CSR


class SolveEngine:
    """Multi-tenant batched SpTRSV server over one session context.

    ``plan_store`` takes a :class:`repro.service.planstore.PlanStore` or a
    directory path (coerced); ``cache_capacity`` bounds the context's
    compiled-executor cache (LRU, ``session.evictions``) — both are what turn
    the session API into something a long-lived multi-tenant worker can run.
    """

    def __init__(self, mesh=None, options=None, *,
                 plan_store: PlanStore | str | None = None,
                 queue: SolveQueue | None = None, registry=None,
                 cache_capacity: int | None = None, max_batch: int = 8,
                 max_wait_s: float = 0.0, max_pending: int = 1024):
        from repro.api import SpTRSVContext

        self.registry = registry if registry is not None else get_registry()
        if isinstance(plan_store, str):
            plan_store = PlanStore(plan_store, registry=self.registry)
        self.plan_store = plan_store
        self.queue = queue if queue is not None else SolveQueue(
            max_batch=max_batch, max_wait_s=max_wait_s,
            max_pending=max_pending)
        self.ctx = SpTRSVContext(mesh=mesh, options=options,
                                 registry=self.registry,
                                 plan_store=plan_store,
                                 cache_capacity=cache_capacity)
        self._counters: collections.Counter = collections.Counter()
        self._stop_flag = threading.Event()
        self._thread: threading.Thread | None = None

    # -- bookkeeping -------------------------------------------------------

    def _count(self, name: str, v: int = 1) -> None:
        self._counters[name] += v
        self.registry.counter(f"service.{name}").inc(v)

    def _observe_depth(self) -> None:
        self.registry.gauge("service.queue_depth").set(self.queue.depth)
        if self.plan_store is not None:
            self.registry.gauge("service.plan_store_hit_rate").set(
                self.plan_store.stats["hit_rate"])

    def stats(self) -> dict:
        """Engine counters (the ground truth the ``service.*`` registry
        counters are reconciled against) plus live queue depth, the plan
        store's counters, and the underlying session's counters."""
        c = dict(self._counters)
        c["queue_depth"] = self.queue.depth
        if self.plan_store is not None:
            c["plan_store"] = self.plan_store.stats
        c["session"] = self.ctx.stats()
        return c

    # -- request intake ----------------------------------------------------

    def submit(self, tenant: str, matrix: CSR, rhs: np.ndarray, *,
               transpose: bool = False) -> Ticket:
        """Enqueue one tenant solve; returns the ticket whose ``result()``
        blocks until a batch containing it is served. Raises
        :class:`repro.service.queue.QueueFull` under backpressure."""
        ticket = self.queue.submit(tenant, matrix, rhs, transpose=transpose)
        self._count("requests")
        self._observe_depth()
        return ticket

    # -- serve loop --------------------------------------------------------

    def step(self, *, force: bool = True) -> int:
        """Serve one admitted batch; returns the number of requests resolved
        (0 when nothing is ready). ``force=False`` honours the admission
        window (the background loop); the default drains unconditionally."""
        batch = self.queue.next_batch(force=force)
        if not batch:
            self._observe_depth()
            return 0
        reqs = [t.request for t in batch]
        t0 = time.perf_counter()
        with get_tracer().span("service.batch", pattern=reqs[0].pattern,
                               n_requests=len(batch),
                               tenants=len({r.tenant for r in reqs})) as span:
            try:
                # analyse is a pattern-cache (or plan-store) hit when warm;
                # changed values on a hot pattern factorize in place
                handle = self.ctx.analyse(reqs[0].matrix)
                panel, r = self.queue.coalesce(batch)
                x = np.asarray(self.ctx.solve(handle, panel,
                                              transpose=reqs[0].transpose))
                self.queue.scatter(batch, x)
            except Exception as e:
                for t in batch:
                    t._resolve(error=e)
                self._count("errors", len(batch))
                span.set(error=type(e).__name__)
                self._observe_depth()
                return len(batch)
            rp = panel.shape[1]
            span.set(width=r, padded_width=rp)
        batch_us = (time.perf_counter() - t0) * 1e6
        self._count("batches")
        self._count("solves")
        self._count("results", len(batch))
        self._count("coalesced_columns", r)
        self._count("pad_columns", rp - r)
        self.registry.histogram("service.batch_us").observe(batch_us)
        self.registry.histogram("service.coalesce_width").observe(r)
        tracer = get_tracer()
        for t in batch:
            with tracer.span("service.request", tenant=t.request.tenant,
                             id=t.request.id,
                             latency_us=t.latency_s * 1e6):
                self.registry.histogram("service.request_us").observe(
                    t.latency_s * 1e6)
        self._observe_depth()
        return len(batch)

    def drain(self) -> int:
        """Serve until the queue is empty; returns requests resolved."""
        total = 0
        while True:
            served = self.step(force=True)
            if served == 0 and self.queue.depth == 0:
                return total
            total += served

    # -- background serving ------------------------------------------------

    def start(self) -> "SolveEngine":
        """Serve from a background thread (one engine thread owns all device
        dispatch; tenants submit from any thread and block on tickets)."""
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop_flag.clear()
        tick = max(self.queue.max_wait_s / 4, 1e-3)

        def loop():
            while not self._stop_flag.is_set():
                if self.step(force=False) == 0:
                    # nothing admitted: flush sub-window stragglers, then idle
                    if self.queue.depth == 0 or self.step(force=False) == 0:
                        self._stop_flag.wait(tick)

        self._thread = threading.Thread(target=loop, name="sptrsv-serve",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        self._stop_flag.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.drain()

    def __enter__(self) -> "SolveEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
