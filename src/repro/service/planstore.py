"""Persistent plan store: cross-session symbolic-analysis reuse (ISSUE 9).

The paper's premise is that SpTRSV's dependency analysis must be amortized
across many solves. Inside one process the session API already does that
(:class:`repro.api.SpTRSVContext` caches per pattern); this module extends the
amortization across *processes*: the symbolic analysis — block structure,
partition, compacted schedules, ``step_off``, bucket tables — serializes to
disk keyed by **pattern sha1 x options signature**, so a short-lived worker
deserializes a plan instead of re-running ``build_blocks`` +
``make_partition`` + the schedule construction.

Only the *symbolic* half of a :class:`repro.core.solver.Plan` is stored.
Numeric values (``diag`` / ``tiles`` and the block structure's tile values)
are rehydrated from the caller's matrix through the existing
:func:`repro.core.solver.refresh_plan` path — the same bit-identity-tested
machinery the factorize stage uses — so a loaded plan carries exactly the
values a fresh ``build_plan`` on that matrix would, and a matrix whose
pattern does not match the stored analysis is rejected by the refresh
pattern check rather than silently mis-paired.

Trust boundary: every load runs the static plan verifier
(:func:`repro.verify.verify_plan`, ``strict`` by default) over the hydrated
plan. A truncated file, a wrong version header, or a mutated schedule table
makes ``load`` return ``None`` (counted under ``rejected``) and the caller
falls back to a fresh analysis — the store can only ever *skip* work, never
corrupt a solve or crash the worker.

File format: one ``.plan.npz`` per (pattern, signature) under the store root
— a zip of the symbolic arrays plus a ``meta`` JSON header (format tag,
version, pattern, signature, shapes, the resolved
:class:`~repro.core.solver.SolverConfig`). Writes go to a temp file in the
same directory and ``os.replace`` into place, so concurrent workers never
observe a half-written entry.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import hashlib
import json
import os
import tempfile

import numpy as np

from repro.core.blocking import BlockStructure
from repro.core.partition import Partition
from repro.core.solver import Plan, SolverConfig, refresh_plan
from repro.obs.trace import get_tracer
from repro.sparse.matrix import CSR

FORMAT = "repro-sptrsv-plan"
VERSION = 1

# the symbolic (values-free) arrays of a Plan, stored verbatim; diag/tiles
# and the block structure's numeric tiles are rehydrated via refresh_plan
_BS_ARRAYS = ("off_rows", "off_cols", "block_level", "block_indeg")
_PART_ARRAYS = ("owner", "boundary")
_PLAN_ARRAYS = ("lvl_off", "lvl_bucket", "solve_rows", "upd_tiles", "ex_rows",
                "ex_boundary", "local_rows", "tile_row", "tile_col", "indeg")


def _jsonable_options(options) -> dict:
    d = dataclasses.asdict(options)
    return {k: (v.value if isinstance(v, enum.Enum) else v)
            for k, v in sorted(d.items())}


def options_signature(options, n_devices: int, *, transpose: bool = False) -> str:
    """Stable short hash of everything that shapes the symbolic plan: the
    options (a :class:`repro.api.options.PlanOptions` — auto dimensions
    included, so a warm auto session keys to the same entry its cold run
    saved — or a resolved :class:`SolverConfig`), the device count, and the
    sweep direction. The ``verify`` / ``probe_solves`` knobs are excluded:
    they change how a plan is checked or chosen, never the plan itself."""
    d = _jsonable_options(options)
    d.pop("verify", None)
    d.pop("probe_solves", None)
    d["n_devices"] = int(n_devices)
    d["transpose"] = bool(transpose)
    h = hashlib.sha1(json.dumps(d, sort_keys=True).encode())
    return h.hexdigest()[:16]


class PlanStore:
    """On-disk plan cache under one root directory.

    ``verify`` sets the :func:`repro.verify.verify_plan` level every load must
    pass (``"strict"`` promotes warnings to failures — the serving default:
    a stale or tampered entry is a fresh-analysis fallback, never a wrong
    answer). Counters (:attr:`stats`) are mirrored into the metrics registry
    as ``planstore.*``.
    """

    def __init__(self, root: str, *, verify: str = "strict", registry=None):
        self.root = root
        self.verify = verify
        if registry is None:
            from repro.obs.metrics import get_registry

            registry = get_registry()
        self.registry = registry
        self._counters: collections.Counter = collections.Counter()
        os.makedirs(root, exist_ok=True)

    # -- bookkeeping -------------------------------------------------------

    def _count(self, name: str) -> None:
        self._counters[name] += 1
        self.registry.counter(f"planstore.{name}").inc()

    @property
    def stats(self) -> dict:
        c = dict(self._counters)
        looked = c.get("hits", 0) + c.get("misses", 0) + c.get("rejected", 0)
        c["hit_rate"] = c.get("hits", 0) / looked if looked else 0.0
        return c

    def path_for(self, pattern: str, signature: str) -> str:
        return os.path.join(self.root, f"{pattern}-{signature}.plan.npz")

    # -- save --------------------------------------------------------------

    def save(self, plan: Plan, *, pattern: str, signature: str | None = None,
             options=None) -> str:
        """Persist ``plan``'s symbolic analysis atomically; returns the path.

        ``pattern`` is the matrix's :func:`repro.api.pattern_key`. The key's
        second half comes from ``options`` (the *pre-resolution*
        :class:`~repro.api.options.PlanOptions` — pass it so auto sessions
        warm-start under their auto key) or an explicit ``signature``;
        with neither, the plan's own resolved config signs the entry.
        """
        if signature is None:
            signature = options_signature(
                options if options is not None else plan.config,
                plan.n_devices, transpose=plan.transpose)
        bs, part = plan.bs, plan.part
        meta = {
            "format": FORMAT, "version": VERSION,
            "pattern": pattern, "signature": signature,
            "n": int(bs.n), "B": int(bs.B), "nb": int(bs.nb),
            "n_tiles": int(bs.n_tiles),
            "n_devices": int(plan.n_devices), "n_levels": int(plan.n_levels),
            "transpose": bool(plan.transpose),
            "tiles_width": int(plan.tiles.shape[1]),
            "frontier_caps": [int(v) for v in plan.frontier_caps],
            "buckets": [[int(v) for v in b] for b in plan.buckets],
            "has_step_off": plan.step_off is not None,
            "config": dataclasses.asdict(plan.config),
            "partition": {"strategy": part.strategy,
                          "tasks_per_device": int(part.tasks_per_device)},
        }
        arrays = {f"bs_{k}": np.asarray(getattr(bs, k)) for k in _BS_ARRAYS}
        arrays.update({f"part_{k}": np.asarray(getattr(part, k))
                       for k in _PART_ARRAYS})
        arrays.update({k: np.asarray(getattr(plan, k)) for k in _PLAN_ARRAYS})
        if plan.step_off is not None:
            arrays["step_off"] = np.asarray(plan.step_off)
        path = self.path_for(pattern, signature)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, meta=np.array(json.dumps(meta, sort_keys=True)),
                         **arrays)
            os.replace(tmp, path)  # atomic: readers see old or new, never half
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self._count("saves")
        return path

    # -- load --------------------------------------------------------------

    def load(self, a: CSR, n_devices: int, options=None, *,
             transpose: bool = False, signature: str | None = None
             ) -> Plan | None:
        """Load + hydrate + verify the plan for ``a`` under ``options``.

        Returns ``None`` on a miss *or* on any defect — unreadable file,
        format/version/key mismatch, pattern drift, or a strict
        :func:`repro.verify.verify_plan` finding — so callers need exactly one
        fallback: run the fresh analysis.
        """
        from repro.api.context import pattern_key

        if signature is None:
            if options is None:
                raise ValueError("load needs options or an explicit signature")
            signature = options_signature(options, n_devices,
                                          transpose=transpose)
        pattern = pattern_key(a)
        path = self.path_for(pattern, signature)
        if not os.path.exists(path):
            self._count("misses")
            return None
        with get_tracer().span("planstore.load", pattern=pattern,
                               signature=signature) as span:
            try:
                plan = self._read(path, a, pattern, signature, n_devices,
                                  transpose)
            except Exception as e:  # corrupt/stale: fall back, never crash
                self._count("rejected")
                span.set(rejected=True, reason=type(e).__name__)
                return None
        self._count("hits")
        return plan

    def _read(self, path: str, a: CSR, pattern: str, signature: str,
              n_devices: int, transpose: bool) -> Plan:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"][()]))
            if meta.get("format") != FORMAT:
                raise ValueError(f"not a plan file: {meta.get('format')!r}")
            if meta.get("version") != VERSION:
                raise ValueError(f"unsupported plan version {meta.get('version')!r}")
            for key, want in (("pattern", pattern), ("signature", signature),
                              ("n", a.n), ("n_devices", n_devices),
                              ("transpose", transpose)):
                if meta.get(key) != want:
                    raise ValueError(f"stale entry: {key} {meta.get(key)!r} != {want!r}")
            arrs = {k: z[k] for k in z.files if k != "meta"}
        config = SolverConfig(**meta["config"])
        B, nb, m = int(meta["B"]), int(meta["nb"]), int(meta["n_tiles"])
        # values-free skeleton: identity/zero tiles, replaced wholesale by the
        # refresh below (bit-identical to a fresh build on the same matrix)
        bs = BlockStructure(
            n=int(meta["n"]), B=B, nb=nb,
            diag=np.zeros((nb, B, B), np.float32),
            off_rows=arrs["bs_off_rows"], off_cols=arrs["bs_off_cols"],
            off_tiles=np.zeros((m, B, B), np.float32),
            block_level=arrs["bs_block_level"],
            block_indeg=arrs["bs_block_indeg"],
        )
        part = Partition(
            n_devices=n_devices, strategy=meta["partition"]["strategy"],
            tasks_per_device=int(meta["partition"]["tasks_per_device"]),
            owner=arrs["part_owner"], boundary=arrs["part_boundary"],
        )
        D, ML1 = n_devices, int(meta["tiles_width"])
        skeleton = Plan(
            bs=bs, part=part, config=config, n_devices=D,
            n_levels=int(meta["n_levels"]),
            diag=np.zeros((nb + 1, B, B), np.float32),
            owner=np.concatenate([part.owner, [-1]]).astype(np.int32),
            indeg=arrs["indeg"], ex_rows=arrs["ex_rows"],
            ex_boundary=arrs["ex_boundary"], lvl_off=arrs["lvl_off"],
            lvl_bucket=arrs["lvl_bucket"],
            buckets=tuple(tuple(int(v) for v in b) for b in meta["buckets"]),
            solve_rows=arrs["solve_rows"], upd_tiles=arrs["upd_tiles"],
            local_rows=arrs["local_rows"], tile_row=arrs["tile_row"],
            tile_col=arrs["tile_col"],
            tiles=np.zeros((D, ML1, B, B), np.float32),
            transpose=transpose,
            frontier_caps=tuple(int(v) for v in meta["frontier_caps"]),
            step_off=arrs.get("step_off") if meta.get("has_step_off") else None,
        )
        # hydrate numeric values through the factorize path: validates the
        # block pattern against `a` and rebuilds diag/tiles bit-identically
        plan = refresh_plan(skeleton, a)
        from repro.verify import verify_plan

        verify_plan(plan, level=self.verify).raise_if_failed()
        return plan
