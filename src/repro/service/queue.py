"""Multi-tenant request admission + same-pattern RHS coalescing (ISSUE 9).

Requests are ``(tenant, matrix, rhs)`` solves. The scheduler groups pending
requests by **(pattern sha1, value fingerprint)** — the pattern groups share
one symbolic analysis, and the value fingerprint guarantees every request
coalesced into one panel solves against identical numeric values (a tenant
that refreshed its factor lands in a new group rather than silently reading
another tenant's values). A ready group's RHS vectors are stacked into the
multi-RHS ``(n, R)`` panel the kernels already execute as ``(k, B, R)``
tiles, with ``R`` padded up a small static ladder (powers of two up to
``max_batch``) so a long-lived server compiles at most ``log2(max_batch)+1``
panel widths per pattern instead of one executor per arrival count.

Admission window: a group is dispatchable when it holds ``max_batch``
columns or its oldest request has waited ``max_wait_s`` (0 = always ready —
the synchronous / drain regime). Fairness: when a group holds more columns
than one batch admits, the batch is filled round-robin across tenants, so
one chatty tenant cannot starve the rest of a hot pattern. Backpressure:
``submit`` raises :class:`QueueFull` beyond ``max_pending`` total columns —
the bounded-queue contract a front end can retry/shed against.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
import threading
import time

import numpy as np

from repro.sparse.matrix import CSR


class QueueFull(RuntimeError):
    """Bounded-queue backpressure: the server is at ``max_pending`` columns."""


def value_key(a: CSR) -> str:
    """Fingerprint of the matrix's numeric content (pattern + values)."""
    from repro.api.context import pattern_key

    h = hashlib.sha1()
    h.update(pattern_key(a).encode())
    h.update(np.ascontiguousarray(a.val, dtype=np.float32).tobytes())
    return h.hexdigest()[:16]


def rhs_ladder(max_batch: int) -> tuple:
    """Static panel-width ladder: powers of two up to (and incl.) max_batch."""
    lad = {1 << k for k in range(max_batch.bit_length()) if 1 << k <= max_batch}
    return tuple(sorted(lad | {int(max_batch)}))


def pad_width(ladder: tuple, r: int) -> int:
    """Smallest ladder width >= r (bounds distinct compiled panel widths)."""
    for w in ladder:
        if w >= r:
            return w
    return ladder[-1]


@dataclasses.dataclass
class SolveRequest:
    """One tenant's solve of ``matrix @ x = rhs`` (rhs: ``(n,)`` vector or an
    ``(n, k)`` panel — panels coalesce as k columns and come back as one)."""

    tenant: str
    matrix: CSR
    rhs: np.ndarray
    transpose: bool = False
    id: int = 0
    pattern: str = ""
    vkey: str = ""
    t_submit: float = 0.0

    @property
    def n_columns(self) -> int:
        return int(self.rhs.shape[1]) if self.rhs.ndim == 2 else 1

    @property
    def group(self) -> tuple:
        return (self.pattern, self.vkey, self.transpose)


class Ticket:
    """Caller-side handle for a submitted request; ``result()`` blocks until
    the engine publishes the solution (or re-raises the engine-side error)."""

    def __init__(self, request: SolveRequest):
        self.request = request
        self._done = threading.Event()
        self._result: np.ndarray | None = None
        self._error: BaseException | None = None
        self.latency_s: float = 0.0

    def _resolve(self, result=None, error=None) -> None:
        self._result = result
        self._error = error
        self.latency_s = time.monotonic() - self.request.t_submit
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> np.ndarray:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.request.id} still pending")
        if self._error is not None:
            raise self._error
        return self._result


class SolveQueue:
    """Thread-safe bounded admission queue with pattern-group coalescing."""

    def __init__(self, *, max_batch: int = 8, max_wait_s: float = 0.0,
                 max_pending: int = 1024):
        if max_batch < 1 or max_pending < 1:
            raise ValueError("max_batch and max_pending must be >= 1")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.max_pending = int(max_pending)
        self.ladder = rhs_ladder(self.max_batch)
        self._lock = threading.Lock()
        self._groups: dict = collections.OrderedDict()  # group -> [Ticket]
        self._ids = itertools.count()
        self._n_columns = 0

    # -- producer side -----------------------------------------------------

    def submit(self, tenant: str, matrix: CSR, rhs: np.ndarray, *,
               transpose: bool = False) -> Ticket:
        """Enqueue one solve; raises :class:`QueueFull` at ``max_pending``."""
        from repro.api.context import pattern_key

        rhs = np.asarray(rhs, np.float32)
        if rhs.ndim not in (1, 2) or rhs.shape[0] != matrix.n:
            raise ValueError(
                f"rhs shape {rhs.shape} does not match matrix n={matrix.n}")
        req = SolveRequest(
            tenant=str(tenant), matrix=matrix, rhs=rhs, transpose=transpose,
            pattern=pattern_key(matrix), vkey=value_key(matrix),
            t_submit=time.monotonic(),
        )
        ticket = Ticket(req)
        with self._lock:
            if self._n_columns + req.n_columns > self.max_pending:
                raise QueueFull(
                    f"{self._n_columns} columns pending (max_pending="
                    f"{self.max_pending}); retry or shed load")
            req.id = next(self._ids)
            self._groups.setdefault(req.group, []).append(ticket)
            self._n_columns += req.n_columns
        return ticket

    # -- consumer side -----------------------------------------------------

    @property
    def depth(self) -> int:
        """Pending RHS columns across all groups."""
        with self._lock:
            return self._n_columns

    def _ready(self, tickets: list, now: float, force: bool) -> bool:
        if force:
            return True
        if sum(t.request.n_columns for t in tickets) >= self.max_batch:
            return True
        oldest = min(t.request.t_submit for t in tickets)
        return (now - oldest) >= self.max_wait_s

    def next_batch(self, *, force: bool = False) -> list[Ticket] | None:
        """Admit one group's batch (oldest ready group first), filled
        round-robin across its tenants up to ``max_batch`` columns; ``None``
        when no group is ready. ``force`` ignores the admission window (the
        drain path)."""
        now = time.monotonic()
        with self._lock:
            group = next((g for g, ts in self._groups.items()
                          if self._ready(ts, now, force)), None)
            if group is None:
                return None
            tickets = self._groups[group]
            by_tenant = collections.OrderedDict()
            for t in tickets:
                by_tenant.setdefault(t.request.tenant, collections.deque()).append(t)
            batch, width = [], 0
            while width < self.max_batch:
                progressed = False
                for dq in by_tenant.values():
                    if dq and width + dq[0].request.n_columns <= self.max_batch:
                        t = dq.popleft()
                        batch.append(t)
                        width += t.request.n_columns
                        progressed = True
                if not progressed:
                    break
            if not batch:
                # a single request wider than max_batch: admit it alone (the
                # panel compiles one off-ladder width) rather than wedging
                t = min((dq[0] for dq in by_tenant.values() if dq),
                        key=lambda t: t.request.id)
                for dq in by_tenant.values():
                    if dq and dq[0] is t:
                        dq.popleft()
                batch, width = [t], t.request.n_columns
            left = [t for dq in by_tenant.values() for t in dq]
            if left:
                self._groups[group] = sorted(left, key=lambda t: t.request.id)
            else:
                del self._groups[group]
            self._n_columns -= width
            return sorted(batch, key=lambda t: t.request.id)

    def coalesce(self, batch: list[Ticket]) -> tuple[np.ndarray, int]:
        """Stack a batch's RHS columns into one ``(n, Rp)`` panel, ``Rp``
        padded up the static ladder; returns ``(panel, real_columns)``."""
        cols = [t.request.rhs.reshape(t.request.rhs.shape[0], -1)
                for t in batch]
        panel = np.concatenate(cols, axis=1)
        r = panel.shape[1]
        rp = pad_width(self.ladder, r)
        if rp > r:
            panel = np.pad(panel, ((0, 0), (0, rp - r)))
        return panel, r

    @staticmethod
    def scatter(batch: list[Ticket], x_panel: np.ndarray) -> None:
        """Route a solved panel's columns back to their tickets (padding
        columns dropped; ``(n,)`` requests get ``(n,)`` back)."""
        j = 0
        for t in batch:
            k = t.request.n_columns
            xs = x_panel[:, j:j + k]
            t._resolve(result=xs[:, 0] if t.request.rhs.ndim == 1 else xs)
            j += k
