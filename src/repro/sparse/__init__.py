from repro.sparse.matrix import (
    CSC,
    CSR,
    csc_to_csr,
    csr_to_csc,
    csr_transpose,
    lower_triangular_from_coo,
    reverse_transpose,
)
from repro.sparse import suite
