from repro.sparse.matrix import CSC, CSR, csc_to_csr, csr_to_csc, lower_triangular_from_coo
from repro.sparse import suite
