"""Host-side sparse matrix containers for SpTRSV.

The paper stores ``L`` in CSC (``col_ptr, row_idx, val``) — we keep both CSC
(the paper's input format) and CSR (convenient for row-oriented analysis).
All arrays are numpy (host); the device-side solver consumes the dense-block
structure built in :mod:`repro.core.blocking`.

Every matrix handled here is *unit-structured lower triangular*: square, all
diagonal entries present and nonzero, and no entries above the diagonal.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSC:
    """Compressed sparse column lower-triangular matrix (paper's format)."""

    n: int
    col_ptr: np.ndarray  # (n+1,) int64
    row_idx: np.ndarray  # (nnz,) int32
    val: np.ndarray  # (nnz,) float

    @property
    def nnz(self) -> int:
        return int(self.col_ptr[-1])

    def validate(self) -> None:
        assert self.col_ptr.shape == (self.n + 1,)
        assert self.col_ptr[0] == 0
        assert self.row_idx.shape[0] == self.col_ptr[-1]
        if self.n == 0:  # degenerate: empty matrix is trivially valid
            return
        assert np.all(np.diff(self.col_ptr) >= 1), "missing diagonal"
        # every column starts at its diagonal entry ...
        starts = np.asarray(self.col_ptr[:-1], dtype=np.int64)
        assert np.array_equal(self.row_idx[starts], np.arange(self.n)), (
            "columns must start at the diagonal"
        )
        # ... and row indices ascend strictly within each column
        if self.nnz > 1:
            col_of = np.repeat(np.arange(self.n), np.diff(self.col_ptr))
            same_col = col_of[1:] == col_of[:-1]
            assert np.all(np.diff(self.row_idx)[same_col] > 0), (
                "row indices must ascend within each column"
            )


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row lower-triangular matrix."""

    n: int
    row_ptr: np.ndarray  # (n+1,) int64
    col_idx: np.ndarray  # (nnz,) int32
    val: np.ndarray  # (nnz,) float

    @property
    def nnz(self) -> int:
        return int(self.row_ptr[-1])

    def diagonal(self) -> np.ndarray:
        # Last entry of each row is the diagonal (col_idx sorted ascending, j <= i).
        return self.val[self.row_ptr[1:] - 1]


def csc_to_csr(a: CSC) -> CSR:
    n, nnz = a.n, a.nnz
    counts = np.bincount(a.row_idx, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    col_idx = np.empty(nnz, dtype=np.int32)
    val = np.empty(nnz, dtype=a.val.dtype)
    cols = np.repeat(np.arange(n, dtype=np.int32), np.diff(a.col_ptr))
    # CSC visited column-major means row entries arrive with ascending column — stable fill.
    cursor = row_ptr[:-1].copy()
    order = np.argsort(a.row_idx, kind="stable")
    col_idx_sorted = cols[order]
    val_sorted = a.val[order]
    col_idx[:] = col_idx_sorted
    val[:] = val_sorted
    del cursor
    return CSR(n=n, row_ptr=row_ptr, col_idx=col_idx, val=val)


def csr_to_csc(a: CSR) -> CSC:
    n, nnz = a.n, a.nnz
    counts = np.bincount(a.col_idx, minlength=n)
    col_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=col_ptr[1:])
    rows = np.repeat(np.arange(n, dtype=np.int32), np.diff(a.row_ptr))
    order = np.argsort(a.col_idx, kind="stable")
    return CSC(n=n, col_ptr=col_ptr, row_idx=rows[order].astype(np.int32), val=a.val[order])


def lower_triangular_from_coo(
    n: int, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray | None = None,
    *, rng: np.random.Generator | None = None, diag_dominant: bool = True,
) -> CSR:
    """Build a well-conditioned lower-triangular CSR from strictly-lower COO pattern.

    Ensures: unique entries, full diagonal, strictly-lower ``cols < rows``; if
    ``diag_dominant`` the diagonal is ``1 + sum(|row|)`` so forward substitution
    is numerically benign (needed for float32 comparisons in tests/benches).
    """
    rng = rng or np.random.default_rng(0)
    mask = cols < rows
    rows, cols = rows[mask].astype(np.int64), cols[mask].astype(np.int64)
    key = rows * n + cols
    key, uniq_idx = np.unique(key, return_index=True)
    rows, cols = key // n, key % n
    if vals is None:
        vals = rng.uniform(-1.0, 1.0, size=rows.shape[0])
    else:
        vals = vals[mask][uniq_idx]
    # append diagonal
    all_rows = np.concatenate([rows, np.arange(n)])
    all_cols = np.concatenate([cols, np.arange(n)])
    row_abs_sum = np.zeros(n)
    np.add.at(row_abs_sum, rows, np.abs(vals))
    diag = (1.0 + row_abs_sum) if diag_dominant else rng.uniform(1.0, 2.0, size=n)
    all_vals = np.concatenate([vals, diag])
    order = np.lexsort((all_cols, all_rows))
    all_rows, all_cols, all_vals = all_rows[order], all_cols[order], all_vals[order]
    counts = np.bincount(all_rows, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSR(n=n, row_ptr=row_ptr, col_idx=all_cols.astype(np.int32), val=all_vals)


def csr_transpose(a: CSR) -> CSR:
    """CSR of A^T (a lower-triangular result when A is upper-triangular)."""
    c = csr_to_csc(a)
    return CSR(n=a.n, row_ptr=c.col_ptr.copy(), col_idx=c.row_idx.astype(np.int32),
               val=c.val.copy())


def reverse_transpose(a: CSR) -> CSR:
    """R with ``R[i, j] = A[n-1-j, n-1-i]`` (transpose + reverse both orders).

    For lower-triangular ``L`` this is again *lower*-triangular, and solving
    ``L^T x = y`` is exactly ``R (Px) = Py`` with ``P`` the index-reversal
    permutation — the trick that lets the forward-substitution solver execute
    upper-triangular/transpose solves (the IC(0)/ILU(0) backward sweeps).
    """
    n = a.n
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(a.row_ptr))
    cols = a.col_idx.astype(np.int64)
    nr, nc = n - 1 - cols, n - 1 - rows
    order = np.lexsort((nc, nr))
    nr, nc, v = nr[order], nc[order], a.val[order]
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(nr, minlength=n), out=row_ptr[1:])
    return CSR(n=n, row_ptr=row_ptr, col_idx=nc.astype(np.int32), val=v)


def to_scipy(a: CSR):
    import scipy.sparse as sp

    return sp.csr_matrix((a.val, a.col_idx, a.row_ptr), shape=(a.n, a.n))


def reference_solve(a: CSR, b: np.ndarray) -> np.ndarray:
    """Ground-truth forward substitution via scipy (the correctness oracle)."""
    import scipy.sparse.linalg as spla

    return spla.spsolve_triangular(to_scipy(a).tocsr(), b, lower=True)
