"""Synthetic matrix suite matched to the paper's Table I signatures.

SuiteSparse is unavailable offline, so each test matrix is generated to match
the *structural signature* that drives SpTRSV behaviour (paper §VI-D):

* ``dependency``  = nnz / n            (avg nonzeros per component)
* ``parallelism`` = n / #levels        (avg components solvable per level)

The paper's matrices span 3 regimes: chain-dominated (many levels, tiny
parallelism: chipcool0, pkustk14, shipsec1), balanced (belgium_osm,
delaunay_n20, roadNet-CA, webbase-1M, dblp-2010), and embarrassingly parallel
(nlpkkt160 with 2 levels, dc2, powersim, Wordnet3). Generators below hit a
target (n, avg_deps, #levels) signature; sizes are scaled down with ``scale``
to stay CPU-friendly while preserving the level/parallelism shape.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.matrix import CSR, lower_triangular_from_coo


def random_levelled(
    n: int, levels: int, avg_deps: float, *, seed: int = 0, locality: float = 0.0
) -> CSR:
    """Lower-triangular matrix with ~``levels`` level-sets and ``avg_deps`` nnz/row.

    Rows are assigned to levels round-robin; each row in level t draws one
    mandatory parent from level t-1 (pins the level count) plus Poisson extras
    from any earlier row. ``locality`` in [0,1) biases extra parents toward
    nearby rows (models banded factors like pkustk14/shipsec1).
    """
    rng = np.random.default_rng(seed)
    levels = max(1, min(levels, n))
    lvl = np.arange(n) % levels  # row i sits in level (i % levels)
    # A row's parents must come from strictly earlier rows; to make lvl the true
    # level, row i needs a parent in the previous level with smaller index.
    rows_l, cols_l = [], []
    extra = max(0.0, avg_deps - 2.0)  # -1 diag, -1 mandatory parent
    for i in range(n):
        if lvl[i] == 0:
            continue
        # mandatory parent: most recent row of level lvl[i]-1 before i
        p = i - 1  # row i-1 always has level lvl[i]-1 given round-robin assignment
        rows_l.append(i)
        cols_l.append(p)
        k = rng.poisson(extra)
        if k and i > 1:
            if locality > 0.0:
                span = max(2, int((1.0 - locality) * i))
                lo = max(0, i - span)
                cand = rng.integers(lo, i, size=k)
            else:
                cand = rng.integers(0, i, size=k)
            # keep the level structure exact: extra parents only from earlier levels
            cand = cand[(cand % levels) < lvl[i]]
            rows_l.extend([i] * cand.shape[0])
            cols_l.extend(cand.tolist())
    rows = np.asarray(rows_l, dtype=np.int64)
    cols = np.asarray(cols_l, dtype=np.int64)
    return lower_triangular_from_coo(n, rows, cols, rng=rng)


def block_diagonal_parallel(n: int, n_blocks: int, avg_deps: float, *, seed: int = 0) -> CSR:
    """nlpkkt160-like: independent diagonal blocks -> ~2 levels, huge parallelism."""
    rng = np.random.default_rng(seed)
    bs = max(2, n // n_blocks)
    rows_l, cols_l = [], []
    for i in range(n):
        base = (i // bs) * bs
        k = rng.poisson(max(0.0, avg_deps - 1.0))
        if i > base and k:
            cand = rng.integers(base, i, size=k)
            rows_l.extend([i] * k)
            cols_l.extend(cand.tolist())
    return lower_triangular_from_coo(
        n, np.asarray(rows_l, dtype=np.int64), np.asarray(cols_l, dtype=np.int64), rng=rng
    )


def chain(n: int, *, seed: int = 0) -> CSR:
    """Bidiagonal worst case: n levels, parallelism 1 (pure dependency chain)."""
    rows = np.arange(1, n, dtype=np.int64)
    cols = rows - 1
    return lower_triangular_from_coo(n, rows, cols, rng=np.random.default_rng(seed))


def grid2d_factor(side: int, *, seed: int = 0) -> CSR:
    """Structure of an IC(0)-style factor of a 2D 5-point Laplacian (side*side rows).

    Mimics structured-grid problems (roadNet / delaunay regime): bandwidth
    ``side``, levels ~ O(side), parallelism ~ O(side).
    """
    n = side * side
    i = np.arange(n, dtype=np.int64)
    west = i - 1
    north = i - side
    rows = np.concatenate([i[i % side != 0], i[i >= side]])
    cols = np.concatenate([west[i % side != 0], north[i >= side]])
    return lower_triangular_from_coo(n, rows, cols, rng=np.random.default_rng(seed))


@dataclasses.dataclass(frozen=True)
class SuiteEntry:
    name: str
    build: object  # () -> CSR
    paper_levels: int
    paper_parallelism: float


def table1_suite(scale: float = 1.0) -> list[SuiteEntry]:
    """The 14-matrix Table-I analogue, structurally matched and CPU-scaled."""

    def S(x: int) -> int:
        return max(64, int(x * scale))

    entries = [
        # name                  generator                                        levels  par
        SuiteEntry("belgium_osm", lambda: random_levelled(S(14000), 128, 2.1, seed=1), 631, 2284),
        SuiteEntry("chipcool0", lambda: random_levelled(S(8000), 256, 7.5, seed=2, locality=0.9), 534, 38),
        SuiteEntry("citationCiteseer", lambda: random_levelled(S(12000), 48, 5.3, seed=3), 102, 2632),
        SuiteEntry("dblp-2010", lambda: random_levelled(S(10000), 384, 3.5, seed=4, locality=0.5), 1562, 209),
        SuiteEntry("dc2", lambda: block_diagonal_parallel(S(12000), 96, 3.8, seed=5), 14, 8345),
        SuiteEntry("delaunay_n20", lambda: grid2d_factor(int(np.sqrt(S(16000))), seed=6), 788, 1331),
        SuiteEntry("nlpkkt160", lambda: random_levelled(S(16000), 2, 14.0, seed=7), 2, 4172800),
        SuiteEntry("pkustk14", lambda: random_levelled(S(8000), 512, 49.0, seed=8, locality=0.95), 1075, 141),
        SuiteEntry("powersim", lambda: block_diagonal_parallel(S(6000), 48, 2.6, seed=9), 24, 660),
        SuiteEntry("roadNet-CA", lambda: grid2d_factor(int(np.sqrt(S(14000))), seed=10), 364, 5416),
        SuiteEntry("webbase-1M", lambda: random_levelled(S(12000), 96, 2.3, seed=11), 512, 1953),
        SuiteEntry("Wordnet3", lambda: random_levelled(S(10000), 16, 2.1, seed=12), 37, 2234),
        SuiteEntry("shipsec1", lambda: random_levelled(S(8000), 320, 6.0, seed=13, locality=0.9), 2100, 67),
        SuiteEntry("copter2", lambda: random_levelled(S(8000), 64, 4.4, seed=14), 190, 291),
    ]
    return entries
