from repro.train.optim import adamw_init, adamw_update, cosine_schedule
from repro.train.step import make_train_step
