"""In-house AdamW (+ schedules) over plain pytrees. No optax dependency.

``state_dtype`` controls the moment dtype: float32 default; bfloat16 halves
optimizer HBM for the 400B-class MoE archs (recorded per-cell in
EXPERIMENTS.md §Dry-run). Updates are always computed in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup)
    frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def adamw_init(params, state_dtype=jnp.float32) -> dict:
    z = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params, grads, state, *, lr, b1: float = 0.9, b2: float = 0.95,
    eps: float = 1e-8, weight_decay: float = 0.1, grad_clip: float = 1.0,
):
    step = state["step"] + 1
    # global-norm clip (f32 accumulation)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9)) if grad_clip > 0 else 1.0

    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * jnp.square(g)
        u = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
