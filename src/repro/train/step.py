"""Sharded train step: loss → grad → AdamW, with remat + microbatch accumulation.

`make_train_step` returns a jitted function with explicit in/out shardings
(params/opt-state by the rule engine, batch over the DP axes), donated
params/opt-state buffers, and optional gradient accumulation over
microbatches (`lax.scan`, f32 accumulators). Gradient compression knob
(`grad_allreduce_dtype="bfloat16"`) casts grads before the DP all-reduce —
XLA then reduces in bf16, halving the dominant collective payload (a
beyond-paper optimization evaluated in §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.meshutil import dp_axes as _dp_axes
from repro.distributed.sharding import batch_specs, param_specs
from repro.models.config import ModelConfig
from repro.models.model import loss_fn
from repro.train.optim import adamw_update, cosine_schedule


def make_train_step(
    cfg: ModelConfig,
    mesh: jax.sharding.Mesh,
    *,
    microbatches: int = 1,
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10000,
    remat: bool = True,
    fsdp: bool = False,
    grad_allreduce_dtype: str | None = None,
    example_params=None,
    example_opt=None,
    example_batch=None,
    donate: bool = True,
):
    dp = _dp_axes(mesh)
    fsdp_axes = dp if fsdp else ()

    def step_fn(params, opt_state, batch, step):
        def loss_of(p, b):
            return loss_fn(
                p, cfg, b.get("tokens"), b.get("labels"),
                embeds=b.get("embeds"), enc_embeds=b.get("enc_embeds"),
                remat=remat,
            )

        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            # accumulate in the gradient's own dtype (bf16 for bf16 params):
            # an f32 accumulator would add a full param-sized f32 buffer on top
            # of params+opt — the difference between fitting HBM or not for
            # the 400B MoE cells (EXPERIMENTS.md §Dry-run)
            def acc_step(carry, b):
                loss, grads = jax.value_and_grad(loss_of)(params, b)
                acc_l, acc_g = carry
                acc_g = jax.tree.map(
                    lambda a, g: a + (g / microbatches).astype(a.dtype), acc_g, grads
                )
                return (acc_l + loss / microbatches, acc_g), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros(()), zero_g), mb)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if grad_allreduce_dtype:  # gradient compression for the DP all-reduce
            grads = jax.tree.map(
                lambda g: g.astype(jnp.dtype(grad_allreduce_dtype)), grads
            )
        lr = cosine_schedule(step, peak_lr=peak_lr, warmup=warmup, total=total_steps)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr=lr)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return params, opt_state, metrics

    if example_params is None:
        return step_fn  # un-jitted (tests drive their own jit)

    from repro.distributed.sharding import SSM_WEIGHT_NAMES

    no_tp = SSM_WEIGHT_NAMES if not cfg.ssm_tp else frozenset()
    pspecs = param_specs(example_params, mesh, fsdp_axes=fsdp_axes,
                         no_tp_names=no_tp)
    ospecs = {
        "m": param_specs(example_opt["m"], mesh, fsdp_axes=fsdp_axes,
                         no_tp_names=no_tp),
        "v": param_specs(example_opt["v"], mesh, fsdp_axes=fsdp_axes,
                         no_tp_names=no_tp),
        "step": P(),
    }
    bspecs = batch_specs(example_batch, mesh, dp_axes=dp)
    shard = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P),
    )
    psh, osh, bsh = shard(pspecs), shard(ospecs), shard(bspecs)
    jitted = jax.jit(
        step_fn,
        in_shardings=(psh, osh, bsh, NamedSharding(mesh, P())),
        out_shardings=(psh, osh, shard({
            "loss": P(), "gnorm": P(), "lr": P()})),
        donate_argnums=(0, 1) if donate else (),
    )

    def stepper(params, opt_state, batch, step):
        # place inputs onto the production sharding (no-op once they are);
        # fresh host arrays / restored checkpoints reshard here
        params = jax.device_put(params, psh)
        opt_state = jax.device_put(opt_state, osh)
        batch = jax.device_put(batch, bsh)
        return jitted(params, opt_state, batch, step)

    return stepper
