"""Static plan verifier (ISSUE 7 tentpole): happens-before race detection
over compacted schedules + kernel-contract lint, no device execution.

Front door::

    from repro.verify import verify_plan
    report = verify_plan(plan, level="strict")
    report.raise_if_failed()

Levels: ``basic`` (happens-before only), ``contracts`` (+ kernel lint,
the default), ``strict`` (contracts, warnings fail too). Opt-in at build
time with ``build_plan(..., verify="strict")`` / ``PlanOptions.verify`` /
``REPRO_VERIFY=1`` (env; ``1`` means ``strict``), or at the CLI with
``launch/solve.py --verify``.

Every run emits an ``sptrsv.verify`` trace span and ``verify.*`` metrics
(runs, findings by severity, per-run rule/finding gauges).
"""
from __future__ import annotations

import os

from repro.verify.report import (LEVELS, Finding, PlanVerificationError,
                                 RuleSink, VerificationReport)

__all__ = [
    "Finding",
    "LEVELS",
    "PlanVerificationError",
    "VerificationReport",
    "env_verify_level",
    "verify_plan",
]


def env_verify_level(default: str | None = None) -> str | None:
    """Verification level requested via ``REPRO_VERIFY`` (``None`` = off).

    ``"1"`` (and any other truthy shorthand that is not a level name) means
    ``strict``; ``""``/``"0"`` disable; a level name selects that level.
    """
    raw = os.environ.get("REPRO_VERIFY")
    if raw is None:
        return default
    raw = raw.strip().lower()
    if raw in ("", "0", "off", "none", "false"):
        return None
    return raw if raw in LEVELS else "strict"


def verify_plan(plan, level: str = "contracts") -> VerificationReport:
    """Statically verify a :class:`repro.core.solver.Plan`.

    Pure host-side analysis: reconstructs the dependency DAG from the block
    structure and checks every per-device compacted schedule (and, at
    ``contracts``/``strict``, the fused/streamed kernel's encoding
    invariants) against it. Never traces or executes device code.
    """
    from repro.obs.metrics import get_registry
    from repro.obs.trace import get_tracer
    from repro.verify.contracts import check_contracts
    from repro.verify.happens_before import check_happens_before

    if level not in LEVELS:
        raise ValueError(
            f"invalid verify level: {level!r} (valid: {', '.join(LEVELS)})")
    with get_tracer().span(
        "sptrsv.verify", level=level, sched=plan.config.sched,
        comm=plan.config.comm, n_devices=plan.n_devices,
        n_levels=plan.n_levels, transpose=plan.transpose,
    ) as span:
        sink = RuleSink()
        check_happens_before(plan, sink)
        if level in ("contracts", "strict"):
            check_contracts(plan, sink)
        report = VerificationReport(
            level=level,
            plan={
                "sched": plan.config.sched, "comm": plan.config.comm,
                "partition": plan.config.partition,
                "kernel_backend": plan.config.kernel_backend,
                "n_devices": plan.n_devices, "n_levels": plan.n_levels,
                "nb": plan.bs.nb, "B": plan.bs.B,
                "transpose": plan.transpose,
            },
            findings=tuple(sink.findings),
            rules_checked=tuple(sink.rules_checked),
        )
        span.set(passed=report.passed, n_rules=len(report.rules_checked),
                 n_errors=len(report.errors),
                 n_warnings=len(report.warnings))
        reg = get_registry()
        reg.counter("verify.runs").inc()
        reg.counter("verify.errors").inc(len(report.errors))
        reg.counter("verify.warnings").inc(len(report.warnings))
        if not report.passed:
            reg.counter("verify.failed").inc()
        reg.gauge("verify.last_rules_checked").set(len(report.rules_checked))
        reg.gauge("verify.last_findings").set(len(report.findings))
    return report
