"""Kernel-contract lint: static invariants of the fused/streamed megakernel
(ISSUE 7 tentpole, part 2).

Where ``happens_before`` proves the *schedule* is a legal linearization of
the dependency DAG, this module proves the *encoding* of that schedule
matches what the kernels assume about it. Every check is against a
re-derivation from first principles (pattern, partition, offsets) — except
the scratch shape, which calls the kernel's own single-source allocation
rule (:func:`repro.kernels.superstep.stream_scratch_shapes`) so the lint
tracks the allocation the kernel actually performs.

Rule catalogue (``kc.*``; all errors unless noted):

* ``kc.offsets.cumsum`` — ``lvl_off`` columns are exactly the exclusive
  cumulative sum of the per-level bucket widths (monotonicity follows).
  ``lax.dynamic_slice`` *clamps* out-of-range offsets, so a broken offset
  table reads wrong-but-in-bounds schedule entries — silently.
* ``kc.flats.length`` — each flat array is exactly ``max(1, sum(widths))``
  long (the executors' slice arithmetic assumes no tail gap).
* ``kc.buckets.fit`` — at most ``MAX_BUCKETS`` buckets and every
  ``lvl_bucket`` entry indexes one (the executor compiles one ``lax.switch``
  branch per bucket).
* ``kc.buckets.cover`` — every level's bucket width covers the rows/tiles/
  exchanges actually scheduled at that level on the busiest device
  (an undershooting bucket truncates the level).
* ``kc.stream.ladder`` — the static DMA width ladders are exactly the
  distinct per-level bucket widths: the streamed kernel predicates one
  async-copy start *and* one wait per ladder entry on ``wid[t] == w``, so a
  width outside the ladder moves no data and a stale ladder entry pairs a
  start with no wait.
* ``kc.stream.slices`` — the per-level HBM slices of the schedule-ordered
  stores are disjoint and exactly cover ``[0, sum(widths))`` within the
  store extent (an overlap DMAs one level's tiles into another's compute).
* ``kc.stream.bytes`` — ``stream_dma_bytes_per_solve`` equals the schedule
  footprint recomputed from the slices.
* ``kc.scratch.shape`` — the double-buffered VMEM scratch is
  ``(2, max level slice, B, B)`` per store: the kernel's allocation rule
  evaluated on the ladders must equal the shape derived from the level table.
* ``kc.carry.donation`` — the superstep carries are not donated:
  ``input_output_aliases``/donation in the kernel module would let the
  output windows alias the zero-initialized carry buffer XLA CSEs across
  ``acc``/``x``.
* ``kc.pad.inert`` — every pad sentinel is the inert value the kernels
  assume: identity diagonal at the pad row, zero tile at the pad slot,
  ``nb`` destinations, ``-1`` owner, zero in-degree.
* ``kc.segments.partition`` — fused segments partition ``[0, T)`` in order,
  and every level whose exchange bucket is non-empty *starts* a segment
  (the fused executor psums only at segment starts; an exchange level in
  mid-segment would silently skip its psum). For merged (``dagpart``)
  plans, every segment boundary must additionally sit on a superstep
  boundary — the fused executor grids over *steps*, so a segment split
  mid-group would misalign the grid against the step table.
* ``kc.steps.partition`` — when a merged step table (``plan.step_off``) is
  present it must partition ``[0, T)``: start at 0, increase strictly, end
  at T. Executors index schedules through it; a malformed table reads
  wrong-but-in-bounds slices, silently.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.verify.report import RuleSink

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.core.solver import Plan


def _widths(plan: "Plan") -> np.ndarray:
    """(T, 3) per-level bucket widths, robust to corrupt bucket ids (flagged
    separately by ``kc.buckets.fit``)."""
    bid = np.clip(plan.lvl_bucket, 0, len(plan.buckets) - 1)
    return np.asarray(plan.buckets, dtype=np.int64)[bid]


def _valid_step_off(plan: "Plan") -> np.ndarray | None:
    """``plan.step_off`` as a validated int64 array (identity for unmerged
    plans), or ``None`` when the table cannot partition ``[0, T)`` —
    downstream checks must then skip step-granular derivations rather than
    cascade off bad data (``kc.steps.partition`` owns the finding)."""
    T = plan.n_levels
    if plan.step_off is None:
        return np.arange(T + 1, dtype=np.int64)
    so = np.asarray(plan.step_off, dtype=np.int64).ravel()
    if (so.size < 1 or int(so[0]) != 0 or int(so[-1]) != T
            or (so.size > 1 and np.any(np.diff(so) <= 0))):
        return None
    return so


def check_contracts(plan: "Plan", sink: RuleSink) -> None:
    _check_offsets(plan, sink)
    steps_ok = _check_steps(plan, sink)
    ids_ok = _check_buckets(plan, sink)
    _check_pad_inert(plan, sink)
    _check_donation(sink)
    # the segment/streaming helpers index `buckets` with `lvl_bucket`
    # unclamped (the builders guarantee validity); once kc.buckets.fit has
    # flagged a corrupt id — or kc.steps.partition a corrupt step table —
    # there is nothing sound left to derive from them
    if plan.config.sched in ("levelset", "dagpart") and ids_ok and steps_ok:
        _check_segments(plan, sink)
        _check_streaming(plan, sink)


def _check_steps(plan: "Plan", sink: RuleSink) -> bool:
    sink.check("kc.steps.partition")
    if plan.step_off is None:
        return True
    if _valid_step_off(plan) is None:
        so = np.asarray(plan.step_off).ravel()
        sink.fail(
            "kc.steps.partition",
            f"step_off {so.tolist()} does not partition [0, {plan.n_levels}) "
            "into merged supersteps (must start at 0, increase strictly, and "
            f"end at {plan.n_levels})",
        )
        return False
    return True


def _check_offsets(plan: "Plan", sink: RuleSink) -> None:
    sink.check("kc.offsets.cumsum")
    sink.check("kc.flats.length")
    wid = _widths(plan)
    T = plan.n_levels
    names = ("solve", "update", "exchange")
    flats = (plan.solve_rows.shape[1], plan.upd_tiles.shape[1],
             plan.ex_rows.shape[0])
    for col, name in enumerate(names):
        w = wid[:, col] if T else np.zeros(0, np.int64)
        expect = np.concatenate([[0], np.cumsum(w)[:-1]]) if T else w
        got = plan.lvl_off[:, col]
        if not np.array_equal(got, expect):
            t = int(np.nonzero(got != expect)[0][0])
            sink.fail(
                "kc.offsets.cumsum",
                f"{name} offsets are not the cumulative sum of the bucket "
                f"widths (first mismatch: lvl_off[{t}]={int(got[t])}, "
                f"expected {int(expect[t])})", level=t,
            )
        want_len = max(1, int(w.sum()))
        if flats[col] != want_len:
            sink.fail(
                "kc.flats.length",
                f"{name} flat has length {flats[col]}, schedule widths sum "
                f"to {want_len}",
            )


def _check_buckets(plan: "Plan", sink: RuleSink) -> bool:
    """Returns whether every ``lvl_bucket`` id is in range (downstream
    checks re-derive widths through the executors' own unclamped lookups)."""
    from repro.core.solver import MAX_BUCKETS

    sink.check("kc.buckets.fit")
    sink.check("kc.buckets.cover")
    if len(plan.buckets) > MAX_BUCKETS:
        sink.fail("kc.buckets.fit",
                  f"{len(plan.buckets)} buckets exceed MAX_BUCKETS="
                  f"{MAX_BUCKETS}")
    bad = [t for t, b in enumerate(plan.lvl_bucket)
           if not 0 <= int(b) < len(plan.buckets)]
    for t in bad:
        sink.fail("kc.buckets.fit",
                  f"lvl_bucket[{t}]={int(plan.lvl_bucket[t])} indexes no "
                  "bucket", level=t)

    # required widths, re-derived from pattern + partition (level-set layout:
    # level t's slice holds block-level-t rows/tiles/boundary rows)
    bs, part, D = plan.bs, plan.part, plan.n_devices
    T = plan.n_levels
    if T == 0:
        return not bad
    lvl = np.asarray(bs.block_level, dtype=np.int64)
    owner = np.asarray(part.owner)
    wid = _widths(plan)
    need = np.zeros((T, 3), dtype=np.int64)
    for d in range(D):
        mine = owner == d
        if mine.any():
            cnt = np.bincount(lvl[mine], minlength=T)[:T]
            need[:, 0] = np.maximum(need[:, 0], cnt)
        tmine = owner[bs.off_cols] == d
        if tmine.any():
            cnt = np.bincount(lvl[bs.off_cols[tmine]], minlength=T)[:T]
            need[:, 1] = np.maximum(need[:, 1], cnt)
    b_rows = np.nonzero(part.boundary)[0]
    if b_rows.size:
        exn = np.bincount(lvl[b_rows], minlength=T)[:T]
        if plan.config.sched == "dagpart" and plan.step_off is not None:
            so = _valid_step_off(plan)
            if so is None:
                exn = np.zeros(T, dtype=np.int64)  # kc.steps owns the finding
            else:
                # the builder hoists each merge group's exchange rows into
                # the group's first micro-level: the need is per *group*,
                # carried entirely by its start level
                cs = np.concatenate([[0], np.cumsum(exn)])
                hoisted = np.zeros(T, dtype=np.int64)
                hoisted[so[:-1]] = cs[so[1:]] - cs[so[:-1]]
                exn = hoisted
        need[:, 2] = exn
    names = ("solve", "update", "exchange")
    for col, name in enumerate(names):
        short = np.nonzero(wid[:, col] < need[:, col])[0]
        for t in short[: 4]:
            sink.fail(
                "kc.buckets.cover",
                f"level {int(t)} {name} bucket width {int(wid[t, col])} "
                f"undershoots the {int(need[t, col])} entries scheduled "
                "there (the slice truncates the level)", level=int(t),
            )
    return not bad


def _check_pad_inert(plan: "Plan", sink: RuleSink) -> None:
    sink.check("kc.pad.inert")
    nb, B = plan.bs.nb, plan.bs.B
    if not np.array_equal(plan.diag[-1], np.eye(B, dtype=plan.diag.dtype)):
        sink.fail("kc.pad.inert",
                  "diag pad slot is not the identity (pad solves would "
                  "produce non-finite garbage)")
    if plan.tiles.size and np.any(plan.tiles[:, -1] != 0):
        sink.fail("kc.pad.inert",
                  "tile pad slot is not the zero tile (pad updates would "
                  "inject garbage into acc)")
    for name, arr, want in (("owner", plan.owner[-1:], -1),
                            ("indeg", plan.indeg[-1:], 0),
                            ("tile_row pad", plan.tile_row[:, -1], nb),
                            ("tile_col pad", plan.tile_col[:, -1], nb)):
        if np.any(np.asarray(arr) != want):
            sink.fail("kc.pad.inert",
                      f"{name} sentinel is not {want}")


def _check_donation(sink: RuleSink) -> None:
    """The carries must not be donated (see the aliasing note at the
    ``pallas_call`` site): lint the kernel module's source for donation."""
    import inspect

    from repro.kernels import superstep

    sink.check("kc.carry.donation")
    src = inspect.getsource(superstep)
    for needle in ("input_output_aliases=", "donate_argnums="):
        if needle in src:
            sink.fail(
                "kc.carry.donation",
                f"kernels/superstep.py passes {needle.rstrip('=')} — carries "
                "must not alias their inputs (acc/x share a CSE'd zero "
                "buffer)",
            )


def _check_segments(plan: "Plan", sink: RuleSink) -> None:
    from repro.core.solver import fused_segments

    sink.check("kc.segments.partition")
    segs = np.asarray(fused_segments(plan))
    T = plan.n_levels
    if T == 0:
        if len(segs):
            sink.fail("kc.segments.partition",
                      "0-level plan has fused segments")
        return
    flat = []
    for lo, hi in segs:
        if hi <= lo:
            sink.fail("kc.segments.partition",
                      f"empty fused segment [{int(lo)}, {int(hi)})")
        flat.extend(range(int(lo), int(hi)))
    if flat != list(range(T)):
        sink.fail(
            "kc.segments.partition",
            f"fused segments {segs.tolist()} do not partition [0, {T}) "
            "in order",
        )
        return
    if plan.config.sched == "dagpart":
        # the fused executor grids over merged steps: a segment boundary
        # inside a merge group would shear the grid against the step table
        so = _valid_step_off(plan)
        bounds = set() if so is None else {int(v) for v in so}
        for lo, hi in segs:
            for edge in (int(lo), int(hi)):
                if edge not in bounds:
                    sink.fail(
                        "kc.segments.partition",
                        f"fused segment edge {edge} splits a merged "
                        "superstep (segment boundaries must sit on "
                        f"step_off boundaries {sorted(bounds)})",
                        level=edge if edge < T else None,
                    )
    if (plan.config.comm == "zerocopy" and plan.n_devices > 1
            and plan.n_boundary_rows > 0):
        wid = _widths(plan)
        starts = {int(lo) for lo, _ in segs}
        for t in range(T):
            if wid[t, 2] > 0 and t not in starts:
                sink.fail(
                    "kc.segments.partition",
                    f"level {t} has a non-empty exchange bucket but sits "
                    "mid-segment — the fused executor psums only at segment "
                    "starts, so this exchange never runs", level=t,
                )


def _check_streaming(plan: "Plan", sink: RuleSink) -> None:
    from repro.core.solver import (stream_dma_bytes_per_solve, stream_widths,
                                   streamed_stores)
    from repro.kernels.superstep import stream_scratch_shapes

    for rule in ("kc.stream.ladder", "kc.stream.slices", "kc.stream.bytes",
                 "kc.scratch.shape"):
        sink.check(rule)
    B = plan.bs.B
    T = plan.n_levels
    wid = _widths(plan)
    # the streamed kernel DMAs one burst per merged superstep, spanning the
    # step's whole contiguous run of level slices — ladders and scratch are
    # therefore sized against per-*step* summed widths (identical to the
    # per-level widths for unmerged plans)
    so = _valid_step_off(plan)
    if so is None:  # pragma: no cover - gated by kc.steps.partition upstream
        return
    cs = np.zeros((T + 1, 3), dtype=np.int64)
    if T:
        np.cumsum(wid, axis=0, out=cs[1:])
    swid = cs[so[1:]] - cs[so[:-1]]
    n_steps = swid.shape[0]
    sw, uw = stream_widths(plan)
    for name, lad, col in (("solve", sw, 0), ("update", uw, 1)):
        actual = ({int(w) for w in swid[:, col]} if n_steps else {0})
        if set(lad) != actual:
            sink.fail(
                "kc.stream.ladder",
                f"{name} DMA ladder {sorted(lad)} != distinct superstep "
                f"widths {sorted(actual)} (a width outside the ladder moves "
                "no data; a stale entry pairs a DMA start with no wait)",
            )

    diag_sched, tiles_sched = streamed_stores(plan)
    extents = (diag_sched.shape[1], tiles_sched.shape[1])
    total = 0
    for name, col, extent in (("solve", 0, extents[0]),
                              ("update", 1, extents[1])):
        cover = np.zeros(extent, dtype=np.int64)
        for t in range(T):
            lo = int(plan.lvl_off[t, col])
            hi = lo + int(wid[t, col])
            if lo < 0 or hi > extent:
                sink.fail(
                    "kc.stream.slices",
                    f"level {t} {name} slice [{lo}, {hi}) leaves the store "
                    f"extent [0, {extent})", level=t,
                )
                continue
            cover[lo:hi] += 1
        total += int(wid[:, col].sum()) if T else 0
        over = np.nonzero(cover > 1)[0]
        if over.size:
            sink.fail(
                "kc.stream.slices",
                f"{over.size} {name} store slots are claimed by more than "
                f"one level slice (first at flat index {int(over[0])}) — "
                "overlapping DMA bursts feed one level another level's "
                "tiles",
            )
        used = int(wid[:, col].sum()) if T else 0
        gap = np.nonzero(cover[:used] == 0)[0]
        if gap.size:
            sink.fail(
                "kc.stream.slices",
                f"{gap.size} {name} store slots inside the schedule "
                f"footprint are covered by no level slice (first at flat "
                f"index {int(gap[0])})",
            )

    want_bytes = total * B * B * 4
    got_bytes = stream_dma_bytes_per_solve(plan)
    if got_bytes != want_bytes:
        sink.fail(
            "kc.stream.bytes",
            f"stream_dma_bytes_per_solve reports {got_bytes} but the "
            f"schedule footprint is {want_bytes} bytes",
        )

    dshape, tshape = stream_scratch_shapes(sw, uw, B)
    want_d = (2, max([int(w) for w in swid[:, 0] if w > 0] or [1])
              if n_steps else 1, B, B)
    want_t = (2, max([int(w) for w in swid[:, 1] if w > 0] or [1])
              if n_steps else 1, B, B)
    if T == 0:
        want_d = want_t = (2, 1, B, B)
    for name, got, want in (("diag", dshape, want_d), ("tile", tshape, want_t)):
        if tuple(got) != tuple(want):
            sink.fail(
                "kc.scratch.shape",
                f"{name} scratch is {tuple(got)}, contract requires "
                f"(2, max level slice, B, B) = {tuple(want)}",
            )
