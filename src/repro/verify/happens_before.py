"""Happens-before race detection over compacted schedules (ISSUE 7 tentpole).

Reconstructs the dependency DAG from the block structure (``off_rows`` /
``off_cols`` — the ground-truth sparsity, *not* the builder's own
``block_level`` analysis) and replays every per-device compacted schedule
positionally, proving the executors' bulk-synchronous timeline respects every
dependency. The semantics are **positional**, not level-identity: a tile
update scheduled in superstep ``t`` is legal whenever its source row's solve
lands in an earlier superstep *or earlier in the same superstep* (solves
precede updates inside one fused/switch superstep body) — exactly the
legality condition the DAG-partition scheduler (``sched="dagpart"``) must
satisfy when it merges levels, which is what makes this module the legality
oracle gating that scheduler: merged plans replay through the *same* walks
(micro-level in-superstep order is exactly the kernel's sequential rowsweep),
plus one merged-step-specific rule for unified comm below.

Executor timeline being modelled (one superstep ``t``, all executors):

    exchange(t)  →  solve slice t  →  update slice t  →  exchange(t+1) → ...

Rule catalogue (``hb.*``; all errors unless noted):

* ``hb.dag.lower-triangular`` — every off-diagonal tile has ``col < row``
  (the quotient graph is acyclic by construction; a violation poisons every
  downstream ordering claim).
* ``hb.solve.range`` / ``hb.solve.owner`` / ``hb.solve.once`` — every real
  block row is solved exactly once, on exactly the device that owns it, and
  every scheduled entry is a valid row inside a level slice.
* ``hb.upd.range`` / ``hb.upd.owner`` / ``hb.upd.once`` / ``hb.upd.pattern``
  — per-device tile stores are a bijection with the pattern's tiles (each
  tile resident exactly once, on its source column's owner), and every real
  store slot is scheduled exactly once.
* ``hb.upd.src-before`` — a tile update's source row is solved in an earlier
  superstep, or earlier in in-superstep order (solves-before-updates).
* ``hb.upd.dest-after`` — a tile update lands strictly before its
  destination row's solve (same-superstep is a race: the superstep body
  solves *before* updating, so the contribution would be lost). For merged
  (``dagpart``) plans "superstep" here means micro-level: the in-kernel
  rowsweep runs each merged micro-level's solves before its updates.
* ``hb.upd.dest-step`` — merged plans under ``comm="unified"`` only: a
  *cross-device* tile update must land in a strictly earlier merged
  superstep than its destination row's solve. The unified executor folds
  the cross-device delta into ``acc`` only at superstep boundaries, so a
  remote contribution computed in the same merged step as the destination
  solve — even at an earlier micro-level — never reaches the owner.
* ``hb.exchange.gate`` / ``hb.exchange.missing`` / ``hb.exchange.once`` /
  ``hb.exchange.position`` — every cross-device dependency is covered by an
  exchange that executes after the last remote update into the row and no
  later than the row's solve superstep, exactly once (a second psum of an
  already-combined row multiplies the pre-exchange contributions by the
  device count — silent wrong answers).
* ``hb.exchange.spurious`` (warning) — a row is exchanged though no remote
  device contributes to it (correct, but pure pad traffic).
* ``hb.exchange.degenerate`` (warning) — the plan schedules collective
  traffic (``comm_bytes_per_solve > 0`` or per-level fused segmentation)
  over an *empty* dependency cut: every update is device-local, so every
  psum carries zeros and every extra launch split is pure overhead.
* ``hb.syncfree.caps`` — ``frontier_caps`` are true upper bounds on the
  runtime frontier. The syncfree executor marks *all* ready rows solved even
  when the dispatched branch width is smaller, so an undershooting cap
  silently drops solves — wrong answers, not a crash.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.verify.report import WARNING, RuleSink

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.core.solver import Plan


def _recompute_levels(nb: int, off_rows: np.ndarray, off_cols: np.ndarray
                      ) -> np.ndarray:
    """Block levels from the tile pattern alone — an independent
    reimplementation of the wavefront analysis (used only for the syncfree
    frontier-cap bound, where the runtime discovers exactly these levels)."""
    lvl = np.zeros(nb, dtype=np.int64)
    order = np.argsort(off_rows, kind="stable")
    sr, sc = off_rows[order], off_cols[order]
    ptr = np.searchsorted(sr, np.arange(nb + 1))
    for r in range(nb):
        lo, hi = ptr[r], ptr[r + 1]
        if hi > lo:
            lvl[r] = lvl[sc[lo:hi]].max() + 1
    return lvl


def _level_slices(plan: "Plan", col: int, flat_len: int) -> list:
    """``[(t, lo, hi), ...]`` clamped slices of schedule column ``col``
    (0=solve, 1=update, 2=exchange). Malformed offsets are clamped here and
    *flagged* by the kernel-contract lint (``kc.offsets.cumsum``); the
    happens-before walk then reports what the clamped schedule actually
    executes (dropped rows surface as ``hb.solve.once`` etc.)."""
    bid = np.clip(plan.lvl_bucket, 0, len(plan.buckets) - 1)
    wid = np.asarray(plan.buckets, dtype=np.int64)[bid]
    out = []
    for t in range(plan.n_levels):
        lo = int(plan.lvl_off[t, col])
        hi = lo + int(wid[t, col])
        out.append((t, max(0, min(lo, flat_len)), max(0, min(hi, flat_len))))
    return out


def _step_of_levels(plan: "Plan") -> np.ndarray | None:
    """Micro-level -> merged-superstep map from ``plan.step_off``; identity
    for unmerged plans. ``None`` when the table is malformed — the
    kernel-contract lint (``kc.steps.partition``) owns that finding, and the
    ordering walk must not cascade noise off unusable data."""
    T = plan.n_levels
    if plan.step_off is None:
        return np.arange(T, dtype=np.int64)
    so = np.asarray(plan.step_off).ravel()
    if (so.size < 1 or int(so[0]) != 0 or int(so[-1]) != T
            or (so.size > 1 and np.any(np.diff(so) <= 0))):
        return None
    return np.repeat(np.arange(so.size - 1, dtype=np.int64), np.diff(so))


def check_happens_before(plan: "Plan", sink: RuleSink) -> None:
    bs, part, cfg = plan.bs, plan.part, plan.config
    nb, D = bs.nb, plan.n_devices
    owner = np.asarray(part.owner)
    off_rows = np.asarray(bs.off_rows, dtype=np.int64)
    off_cols = np.asarray(bs.off_cols, dtype=np.int64)

    # --- the dependency DAG itself -------------------------------------
    sink.check("hb.dag.lower-triangular")
    bad = np.nonzero(off_cols >= off_rows)[0]
    if bad.size:
        sink.fail(
            "hb.dag.lower-triangular",
            f"{bad.size} off-diagonal tiles are not strictly lower-triangular",
            tiles=zip(off_rows[bad], off_cols[bad]),
        )
        return  # the DAG is not a DAG; ordering claims below are meaningless

    sink.check("hb.solve.owner")
    if nb and (owner.min() < 0 or owner.max() >= D):
        rows = np.nonzero((owner < 0) | (owner >= D))[0]
        sink.fail("hb.solve.owner",
                  f"{rows.size} rows have an owner outside [0, {D})",
                  rows=rows)
        return

    remote = owner[off_cols] != owner[off_rows]  # tile computed off-owner
    remote_dest = set(np.unique(off_rows[remote]).tolist())
    tile_of = {(int(r), int(c)): i
               for i, (r, c) in enumerate(zip(off_rows, off_cols))}

    if cfg.sched in ("levelset", "dagpart"):
        solve_level = _check_levelset_solves(plan, sink, owner)
        upd_level = _check_levelset_updates(plan, sink, owner, tile_of)
        _check_ordering(plan, sink, solve_level, upd_level, tile_of)
        _check_levelset_exchange(plan, sink, remote_dest, solve_level,
                                 upd_level, tile_of, off_rows, off_cols,
                                 remote)
    else:
        lvl = _recompute_levels(nb, off_rows, off_cols)
        _check_syncfree(plan, sink, owner, tile_of, remote_dest, lvl)

    # --- degenerate communication over an empty cut --------------------
    sink.check("hb.exchange.degenerate")
    if D > 1 and not remote_dest:
        comm = plan.comm_bytes_per_solve
        if comm > 0:
            sink.fail(
                "hb.exchange.degenerate",
                f"plan schedules {comm} collective bytes/solve over an empty "
                "dependency cut (every update is device-local)",
                severity=WARNING,
            )
        if cfg.sched in ("levelset", "dagpart") and all(
                0 <= int(b) < len(plan.buckets) for b in plan.lvl_bucket):
            from repro.core.solver import fused_segments

            n_seg = len(fused_segments(plan))
            if n_seg > 1:
                sink.fail(
                    "hb.exchange.degenerate",
                    f"fused execution splits into {n_seg} launches over an "
                    "empty cut (one launch suffices: no psum is needed)",
                    severity=WARNING,
                )


# -----------------------------------------------------------------------
# levelset schedule walks
# -----------------------------------------------------------------------


def _check_levelset_solves(plan: "Plan", sink: RuleSink, owner: np.ndarray
                           ) -> dict:
    """Walk ``solve_rows`` slices; returns ``{row: superstep}``."""
    nb, D = plan.bs.nb, plan.n_devices
    S = plan.solve_rows.shape[1]
    slices = _level_slices(plan, 0, S)
    for rule in ("hb.solve.range", "hb.solve.owner", "hb.solve.once"):
        sink.check(rule)

    solve_level: dict = {}
    dup: dict = {}
    covered = np.zeros(S, dtype=bool)
    for t, lo, hi in slices:
        covered[lo:hi] = True
        for d in range(D):
            for r in plan.solve_rows[d, lo:hi]:
                r = int(r)
                if r == -1:
                    continue  # pad
                if not 0 <= r < nb:
                    sink.fail("hb.solve.range",
                              f"solve entry {r} outside [0, {nb})",
                              level=t, device=d)
                    continue
                if int(owner[r]) != d:
                    sink.fail(
                        "hb.solve.owner",
                        f"row {r} scheduled on device {d} but owned by "
                        f"device {int(owner[r])}", level=t, device=d, rows=[r],
                    )
                if r in solve_level:
                    dup.setdefault(r, [solve_level[r]]).append(t)
                else:
                    solve_level[r] = t
    for d in range(D):
        stray = [int(r) for r in plan.solve_rows[d][~covered] if int(r) != -1]
        if stray:
            sink.fail(
                "hb.solve.range",
                f"{len(stray)} solve entries sit outside every level slice "
                "(never executed)", device=d, rows=stray,
            )
    if dup:
        for r, lvls in dup.items():
            sink.fail(
                "hb.solve.once",
                f"row {r} solved {len(lvls)} times (supersteps {lvls})",
                rows=[r],
            )
    missing = [r for r in range(nb) if r not in solve_level]
    if missing:
        sink.fail(
            "hb.solve.once",
            f"{len(missing)} rows are never solved by any device's schedule",
            rows=missing,
        )
    return solve_level


def _resident_slots(plan: "Plan", d: int) -> list:
    """Real tile slots of device ``d``'s store (pad slots carry dest ``nb``)."""
    nb = plan.bs.nb
    ML = plan.tiles.shape[1] - 1
    return [s for s in range(ML) if int(plan.tile_row[d, s]) != nb]


def _check_tile_stores(plan: "Plan", sink: RuleSink, owner: np.ndarray,
                       tile_of: dict) -> None:
    """Store/pattern bijection: every pattern tile resident exactly once, on
    its source column's owner; no fabricated tiles."""
    for rule in ("hb.upd.pattern", "hb.upd.owner"):
        sink.check(rule)
    seen: dict = {}
    for d in range(plan.n_devices):
        for s in _resident_slots(plan, d):
            r, c = int(plan.tile_row[d, s]), int(plan.tile_col[d, s])
            if (r, c) not in tile_of:
                sink.fail("hb.upd.pattern",
                          f"device {d} store slot {s} holds tile ({r},{c}) "
                          "absent from the matrix pattern",
                          device=d, tiles=[(r, c)])
                continue
            if int(owner[c]) != d:
                sink.fail(
                    "hb.upd.owner",
                    f"tile ({r},{c}) resident on device {d} but its source "
                    f"column is owned by device {int(owner[c])}",
                    device=d, tiles=[(r, c)],
                )
            if (r, c) in seen:
                sink.fail("hb.upd.pattern",
                          f"tile ({r},{c}) resident on devices "
                          f"{seen[(r, c)]} and {d}", tiles=[(r, c)])
            seen[(r, c)] = d
    absent = [rc for rc in tile_of if rc not in seen]
    if absent:
        sink.fail(
            "hb.upd.pattern",
            f"{len(absent)} pattern tiles are resident on no device "
            "(their updates can never execute)", tiles=absent,
        )


def _check_levelset_updates(plan: "Plan", sink: RuleSink, owner: np.ndarray,
                            tile_of: dict) -> dict:
    """Walk ``upd_tiles`` slices; returns ``{(dest, src): superstep}``."""
    nb, D = plan.bs.nb, plan.n_devices
    ML = plan.tiles.shape[1] - 1
    U = plan.upd_tiles.shape[1]
    slices = _level_slices(plan, 1, U)
    for rule in ("hb.upd.range", "hb.upd.once"):
        sink.check(rule)
    _check_tile_stores(plan, sink, owner, tile_of)

    upd_level: dict = {}
    scheduled: dict = {}
    for t, lo, hi in slices:
        for d in range(D):
            for s in plan.upd_tiles[d, lo:hi]:
                s = int(s)
                if s == ML:
                    continue  # pad slot (zero tile, dest nb)
                if not 0 <= s < ML:
                    sink.fail("hb.upd.range",
                              f"update entry {s} outside [0, {ML}]",
                              level=t, device=d)
                    continue
                r, c = int(plan.tile_row[d, s]), int(plan.tile_col[d, s])
                if r == nb:
                    continue  # unfilled store slot: zero tile, inert
                if (d, s) in scheduled:
                    sink.fail(
                        "hb.upd.once",
                        f"tile ({r},{c}) updated twice (supersteps "
                        f"{scheduled[(d, s)]} and {t}) — double-counted "
                        "contribution", level=t, device=d, tiles=[(r, c)],
                    )
                else:
                    scheduled[(d, s)] = t
                    upd_level[(r, c)] = t
    for d in range(D):
        missing = [s for s in _resident_slots(plan, d)
                   if (d, s) not in scheduled]
        if missing:
            tiles = [(int(plan.tile_row[d, s]), int(plan.tile_col[d, s]))
                     for s in missing]
            sink.fail(
                "hb.upd.once",
                f"{len(missing)} resident tiles are never scheduled "
                "(their contributions are dropped)", device=d, tiles=tiles,
            )
    return upd_level


def _check_ordering(plan: "Plan", sink: RuleSink, solve_level: dict,
                    upd_level: dict, tile_of: dict) -> None:
    for rule in ("hb.upd.src-before", "hb.upd.dest-after"):
        sink.check(rule)
    for (r, c), t in upd_level.items():
        tc = solve_level.get(c)
        # missing solves were already flagged by hb.solve.once — don't cascade
        if tc is not None and tc > t:
            sink.fail(
                "hb.upd.src-before",
                f"tile ({r},{c}) updates in superstep {t} but its source row "
                f"{c} is only solved in superstep {tc}", level=t,
                tiles=[(r, c)],
            )
        tr = solve_level.get(r)
        if tr is not None and t >= tr:
            sink.fail(
                "hb.upd.dest-after",
                f"tile ({r},{c}) updates in superstep {t} but its "
                f"destination row {r} solves in superstep {tr} "
                "(solves precede updates inside a superstep, so the "
                "contribution is lost)", level=t, tiles=[(r, c)],
            )

    # merged steps under unified comm: the dense delta psum folds into acc
    # only at superstep *boundaries*, so a cross-device update must complete
    # in a strictly earlier merged step than its destination's solve — the
    # micro-level ordering above is not enough once levels share a step
    cfg = plan.config
    if not (cfg.sched == "dagpart" and cfg.comm == "unified"
            and plan.n_devices > 1):
        return
    step_of = _step_of_levels(plan)
    if step_of is None:
        return  # malformed step table: kc.steps.partition owns this
    sink.check("hb.upd.dest-step")
    owner = np.asarray(plan.part.owner)
    for (r, c), t in upd_level.items():
        if int(owner[c]) == int(owner[r]):
            continue  # device-local: the in-step sequential sweep covers it
        tr = solve_level.get(r)
        if tr is None or not (0 <= t < len(step_of) and 0 <= tr < len(step_of)):
            continue  # missing/ranged solves already flagged — don't cascade
        if step_of[t] >= step_of[tr]:
            sink.fail(
                "hb.upd.dest-step",
                f"remote tile ({r},{c}) updates in merged superstep "
                f"{int(step_of[t])} but its destination row {r} solves in "
                f"superstep {int(step_of[tr])} on device {int(owner[r])} — "
                "unified comm folds the cross-device delta only at superstep "
                "boundaries, so the contribution never arrives",
                level=t, tiles=[(r, c)],
            )


def _check_levelset_exchange(plan: "Plan", sink: RuleSink, remote_dest: set,
                             solve_level: dict, upd_level: dict,
                             tile_of: dict, off_rows, off_cols, remote
                             ) -> None:
    cfg = plan.config
    nb, D = plan.bs.nb, plan.n_devices
    if cfg.comm != "zerocopy" or D == 1:
        # unified's dense per-superstep psum covers every remote dependency
        # with update-superstep < solve-superstep, which hb.upd.dest-after
        # already proves; single-device plans have no exchanges at all
        return
    for rule in ("hb.exchange.gate", "hb.exchange.range", "hb.exchange.once",
                 "hb.exchange.missing", "hb.exchange.position",
                 "hb.exchange.spurious"):
        sink.check(rule)
    # the executors gate the packed psum on the partition reporting a
    # non-empty cut: if the gate is off, the ex schedule is dead data
    gate_on = plan.n_boundary_rows > 0
    if not gate_on:
        if remote_dest:
            sink.fail(
                "hb.exchange.gate",
                f"{len(remote_dest)} rows receive remote contributions but "
                "the partition reports an empty cut, so executors skip the "
                "exchange entirely", rows=sorted(remote_dest),
            )
        return

    E = plan.ex_rows.shape[0]
    ex_level: dict = {}
    for t, lo, hi in _level_slices(plan, 2, E):
        for r in plan.ex_rows[lo:hi]:
            r = int(r)
            if r == nb:
                continue  # pad (psum of the inert pad slot)
            if not 0 <= r < nb:
                sink.fail("hb.exchange.range",
                          f"exchange entry {r} outside [0, {nb}]", level=t)
                continue
            if r in ex_level:
                sink.fail(
                    "hb.exchange.once",
                    f"row {r} exchanged twice (supersteps {ex_level[r]} and "
                    f"{t}) — the second psum multiplies already-combined "
                    f"contributions by the device count", level=t, rows=[r],
                )
            else:
                ex_level[r] = t

    # per remote-dependent row: covered, exactly once, correctly positioned
    remote_upds: dict = {}
    for i in np.nonzero(remote)[0]:
        remote_upds.setdefault(int(off_rows[i]), []).append(int(off_cols[i]))
    for r in sorted(remote_dest):
        te = ex_level.get(r)
        if te is None:
            sink.fail(
                "hb.exchange.missing",
                f"row {r} receives remote contributions but is never "
                "exchanged — its solve reads only the local partial sum",
                level=solve_level.get(r), rows=[r],
            )
            continue
        tr = solve_level.get(r)
        if tr is not None and te > tr:
            sink.fail(
                "hb.exchange.position",
                f"row {r} is exchanged in superstep {te}, after its solve in "
                f"superstep {tr}", level=te, rows=[r],
            )
        for c in remote_upds[r]:
            tu = upd_level.get((r, c))
            # exchanges run at the *start* of a superstep, updates at its
            # end: a remote update needs a strictly later exchange to land
            if tu is not None and tu >= te:
                sink.fail(
                    "hb.exchange.position",
                    f"remote update ({r},{c}) lands in superstep {tu} but "
                    f"row {r}'s exchange already ran at the start of "
                    f"superstep {te} — the contribution is stranded on "
                    f"device {int(plan.part.owner[c])}", level=te,
                    rows=[r], tiles=[(r, c)],
                )
    spurious = sorted(set(ex_level) - remote_dest)
    if spurious:
        sink.fail(
            "hb.exchange.spurious",
            f"{len(spurious)} exchanged rows have no remote contributions "
            "(the psum only echoes the local value)", severity=WARNING,
            rows=spurious,
        )


# -----------------------------------------------------------------------
# syncfree plans
# -----------------------------------------------------------------------


def _check_syncfree(plan: "Plan", sink: RuleSink, owner: np.ndarray,
                    tile_of: dict, remote_dest: set, lvl: np.ndarray) -> None:
    nb, D = plan.bs.nb, plan.n_devices
    cfg = plan.config
    for rule in ("hb.solve.range", "hb.solve.owner", "hb.solve.once"):
        sink.check(rule)
    seen: dict = {}
    for d in range(D):
        for r in plan.local_rows[d]:
            r = int(r)
            if r == nb:
                continue  # pad
            if not 0 <= r < nb:
                sink.fail("hb.solve.range",
                          f"local row {r} outside [0, {nb}]", device=d)
                continue
            if int(owner[r]) != d:
                sink.fail("hb.solve.owner",
                          f"row {r} in device {d}'s local set but owned by "
                          f"device {int(owner[r])}", device=d, rows=[r])
            if r in seen:
                sink.fail("hb.solve.once",
                          f"row {r} in local sets of devices {seen[r]} "
                          f"and {d}", device=d, rows=[r])
            seen[r] = d
    missing = [r for r in range(nb) if r not in seen]
    if missing:
        sink.fail("hb.solve.once",
                  f"{len(missing)} rows are in no device's local set "
                  "(the solve never terminates)", rows=missing)

    _check_tile_stores(plan, sink, owner, tile_of)

    # packed boundary exchange (zerocopy): membership + multiplicity. The
    # runtime psums every sweep, so positioning is structural — only coverage
    # can break statically.
    if cfg.comm == "zerocopy" and D > 1:
        for rule in ("hb.exchange.gate", "hb.exchange.once",
                     "hb.exchange.missing", "hb.exchange.spurious"):
            sink.check(rule)
        gate_on = plan.n_boundary_rows > 0
        exb = [int(r) for r in plan.ex_boundary if int(r) != nb]
        if not gate_on:
            if remote_dest:
                sink.fail(
                    "hb.exchange.gate",
                    f"{len(remote_dest)} rows receive remote contributions "
                    "but the partition reports an empty cut, so the runtime "
                    "skips the packed exchange", rows=sorted(remote_dest),
                )
        else:
            counts: dict = {}
            for r in exb:
                counts[r] = counts.get(r, 0) + 1
            dups = sorted(r for r, k in counts.items() if k > 1)
            if dups:
                sink.fail(
                    "hb.exchange.once",
                    f"{len(dups)} rows appear multiple times in ex_boundary "
                    "— scatter-add double-counts their psum", rows=dups,
                )
            missing_ex = sorted(remote_dest - set(counts))
            if missing_ex:
                sink.fail(
                    "hb.exchange.missing",
                    f"{len(missing_ex)} remote-dependent rows missing from "
                    "ex_boundary", rows=missing_ex,
                )
            spurious = sorted(set(counts) - remote_dest)
            if spurious:
                sink.fail(
                    "hb.exchange.spurious",
                    f"{len(spurious)} ex_boundary rows have no remote "
                    "contributions", severity=WARNING, rows=spurious,
                )

    # frontier caps: the ladder's top branch must cover the widest frontier
    # any device can see in any sweep (= its widest block level)
    sink.check("hb.syncfree.caps")
    cap_s, cap_u = int(plan.frontier_caps[0]), int(plan.frontier_caps[1])
    T = int(lvl.max()) + 1 if nb else 0
    need_s = need_u = 0
    for d in range(D):
        mine = owner == d
        if nb:
            need_s = max(need_s, int(np.bincount(
                lvl[mine], minlength=max(T, 1)).max(initial=0)))
        slots = _resident_slots(plan, d)
        if slots:
            src_lvl = lvl[[int(plan.tile_col[d, s]) for s in slots]]
            need_u = max(need_u, int(np.bincount(
                src_lvl, minlength=max(T, 1)).max(initial=0)))
    if need_s > cap_s:
        sink.fail(
            "hb.syncfree.caps",
            f"frontier solve cap {cap_s} undershoots the widest per-device "
            f"level ({need_s} rows) — ready rows beyond the dispatched "
            "branch are marked solved but never computed",
        )
    if need_u > cap_u:
        sink.fail(
            "hb.syncfree.caps",
            f"frontier update cap {cap_u} undershoots the widest per-device "
            f"tile frontier ({need_u} tiles) — their contributions are "
            "silently dropped",
        )
