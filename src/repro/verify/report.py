"""Typed findings and the verification report (ISSUE 7 tentpole, wiring).

Every check in the static plan verifier emits :class:`Finding` records into a
:class:`RuleSink`; :func:`repro.verify.verify_plan` wraps the collected
findings into a :class:`VerificationReport`. Findings are *structured*: a
stable dotted rule id (the catalogue lives in the checker modules' module
docstrings and the README "Plan verification" section), a severity, and the
location — superstep (level) index, device, and the rows/tiles involved — so
tests can assert that a known corruption is flagged with the exact rule at
the exact place, and CI output stays greppable.

Severity semantics:

* ``error``   — the plan would compute a wrong answer (or crash): a
  happens-before violation, a schedule that drops or duplicates work, a
  kernel-contract breach.
* ``warning`` — the plan is correct but degenerate or wasteful (e.g.
  exchange traffic scheduled over an empty dependency cut). The ``strict``
  verification level promotes warnings to failures.
"""
from __future__ import annotations

import dataclasses

ERROR = "error"
WARNING = "warning"

#: Verification levels, weakest to strongest:
#: ``basic``     — happens-before checks only (schedule correctness),
#: ``contracts`` — basic + the kernel-contract lint,
#: ``strict``    — contracts, with warnings promoted to failures.
LEVELS = ("basic", "contracts", "strict")

# rows/tiles listed per finding are capped (the full count still rides in the
# message) so a pathological plan cannot produce a gigabyte report
MAX_ITEMS = 16


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one location."""

    rule: str  # dotted rule id, e.g. "hb.solve.once"
    severity: str  # ERROR | WARNING
    message: str
    level: int | None = None  # superstep (block level) index, when localized
    device: int | None = None
    rows: tuple = ()  # block rows involved (capped at MAX_ITEMS)
    tiles: tuple = ()  # (dest_row, src_col) tile pairs involved (capped)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["rows"] = list(self.rows)
        d["tiles"] = [list(t) for t in self.tiles]
        return d

    def __str__(self) -> str:
        loc = []
        if self.level is not None:
            loc.append(f"level={self.level}")
        if self.device is not None:
            loc.append(f"device={self.device}")
        if self.rows:
            loc.append(f"rows={list(self.rows)}")
        if self.tiles:
            loc.append(f"tiles={[tuple(t) for t in self.tiles]}")
        where = f" [{', '.join(loc)}]" if loc else ""
        return f"{self.severity.upper()} {self.rule}: {self.message}{where}"


class RuleSink:
    """Collector the checkers emit into: records findings and the full set of
    rule ids that *ran* (so a report can show coverage, not just failures)."""

    def __init__(self) -> None:
        self.findings: list[Finding] = []
        self.rules_checked: list[str] = []

    def check(self, rule: str) -> str:
        """Register that ``rule`` ran (idempotent); returns the id."""
        if rule not in self.rules_checked:
            self.rules_checked.append(rule)
        return rule

    def fail(self, rule: str, message: str, *, severity: str = ERROR,
             level: int | None = None, device: int | None = None,
             rows=(), tiles=()) -> Finding:
        self.check(rule)
        f = Finding(
            rule=rule, severity=severity, message=message, level=level,
            device=device, rows=tuple(int(r) for r in tuple(rows)[:MAX_ITEMS]),
            tiles=tuple((int(a), int(b)) for a, b in tuple(tiles)[:MAX_ITEMS]),
        )
        self.findings.append(f)
        return f


@dataclasses.dataclass(frozen=True)
class VerificationReport:
    """The outcome of one :func:`repro.verify.verify_plan` run."""

    level: str  # requested verification level (one of LEVELS)
    plan: dict  # static summary of the verified plan (mode, sizes)
    findings: tuple  # tuple[Finding, ...] in emission order
    rules_checked: tuple  # tuple[str, ...] every rule that ran

    @property
    def errors(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == ERROR)

    @property
    def warnings(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == WARNING)

    @property
    def passed(self) -> bool:
        """No errors; at ``strict`` level, no warnings either."""
        if self.level == "strict":
            return not self.findings
        return not self.errors

    def by_rule(self, rule: str) -> tuple:
        return tuple(f for f in self.findings if f.rule == rule)

    def summary(self) -> str:
        verdict = "PASS" if self.passed else "FAIL"
        return (f"verify[{self.level}] {verdict}: "
                f"{len(self.rules_checked)} rules, "
                f"{len(self.errors)} errors, {len(self.warnings)} warnings")

    def to_dict(self) -> dict:
        return {
            "level": self.level,
            "passed": self.passed,
            "plan": dict(self.plan),
            "rules_checked": list(self.rules_checked),
            "findings": [f.to_dict() for f in self.findings],
        }

    def raise_if_failed(self) -> "VerificationReport":
        if not self.passed:
            raise PlanVerificationError(self)
        return self


class PlanVerificationError(ValueError):
    """A plan failed static verification; carries the full report."""

    def __init__(self, report: VerificationReport):
        self.report = report
        lines = [report.summary()] + [f"  {f}" for f in report.findings]
        super().__init__("\n".join(lines))
