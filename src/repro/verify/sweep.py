"""Suite-wide verifier sweep: ``python -m repro.verify.sweep``.

Builds a plan for every (matrix x partition x sched x comm x kernel x
transpose x device-count) combination in the grid below and runs
:func:`repro.verify.verify_plan` at the ``strict`` level — the CI legality
gate demanded by ISSUE 7's acceptance criteria ("verify_plan passes on every
plan produced by the current builders across the full grid").

Plan construction is pure host-side numpy, so multi-device plans build and
verify without any devices (no mesh, no tracing, no collectives); a sweep
over hundreds of combos runs in seconds on the CI runner.

Exit status: 0 when every plan verifies clean, 1 otherwise (findings are
printed per failing combo).
"""
from __future__ import annotations

import itertools
import sys

import numpy as np

from repro.sparse import suite
from repro.sparse.matrix import CSR, lower_triangular_from_coo


def sweep_matrices() -> dict:
    """The verification corpus: the suite regimes the benches exercise plus
    the degenerate structures that have historically hidden edge cases
    (mirrors ``tests/strategies.py`` without importing from tests/)."""
    rng = np.random.default_rng(11)
    return {
        "skewed": suite.random_levelled(400, 8, 4.0, seed=6),
        "banded": suite.random_levelled(300, 8, 4.0, seed=7, locality=0.8),
        "chain": suite.chain(150),
        "grid": suite.grid2d_factor(18, seed=1),
        "parallel": suite.block_diagonal_parallel(300, 12, 3.0, seed=2),
        "random": lower_triangular_from_coo(
            200, rng.integers(0, 200, 600), rng.integers(0, 200, 600),
            rng=rng),
        "empty": CSR(n=0, row_ptr=np.zeros(1, np.int64),
                     col_idx=np.zeros(0, np.int32),
                     val=np.zeros(0, np.float32)),
        "diagonal": CSR(n=24, row_ptr=np.arange(25, dtype=np.int64),
                        col_idx=np.arange(24, dtype=np.int32),
                        val=np.full(24, 2.0, np.float32)),
        "single": CSR(n=1, row_ptr=np.array([0, 1], np.int64),
                      col_idx=np.zeros(1, np.int32),
                      val=np.array([3.0], np.float32)),
    }


def sweep_grid() -> list:
    """All (partition, sched, comm, kernel, n_devices, transpose) combos."""
    from repro.core.partition import STRATEGIES
    from repro.core.solver import COMM_MODES, SCHED_MODES

    kernels = (None, "fused", "fused_streamed")
    return list(itertools.product(
        STRATEGIES, SCHED_MODES, COMM_MODES, kernels, (1, 4, 8),
        (False, True)))


def run_sweep(level: str = "strict", block_size: int = 8,
              out=sys.stdout) -> int:
    from repro.core.solver import SolverConfig, build_plan
    from repro.verify import verify_plan

    matrices = sweep_matrices()
    grid = sweep_grid()
    n_plans = 0
    failures = []
    for name, a in matrices.items():
        for part, sched, comm, kernel, D, transpose in grid:
            cfg = SolverConfig(block_size=block_size, sched=sched, comm=comm,
                               partition=part, kernel_backend=kernel)
            plan = build_plan(a, D, cfg, transpose=transpose)
            report = verify_plan(plan, level=level)
            n_plans += 1
            if not report.passed:
                combo = (f"{name} x {part}/{sched}/{comm}/"
                         f"{kernel or 'default'}/D={D}"
                         f"{'/transpose' if transpose else ''}")
                failures.append((combo, report))
    for combo, report in failures:
        print(f"FAIL {combo}: {report.summary()}", file=out)
        for f in report.findings:
            print(f"  {f}", file=out)
    verdict = "FAIL" if failures else "PASS"
    print(f"[verify.sweep] {verdict}: {n_plans} plans "
          f"({len(matrices)} matrices x {len(grid)} combos) at "
          f"level={level}, {len(failures)} failing", file=out)
    return 1 if failures else 0


def main() -> None:
    level = sys.argv[1] if len(sys.argv) > 1 else "strict"
    raise SystemExit(run_sweep(level))


if __name__ == "__main__":
    main()
