import os
import sys

# Tests run with the default single CPU device (the dry-run sets its own
# device count in a separate process). Keep kernels on the fast XLA reference
# path by default; kernel tests opt into pallas interpret mode explicitly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# shared generators (tests/strategies.py) import as `strategies` everywhere,
# independent of pytest's import mode
sys.path.insert(0, os.path.dirname(__file__))
