"""Shared triangular-suite generators for the whole test suite.

One home for the matrix builders that used to be re-implemented across
``test_solver`` / ``test_superstep`` / ``test_malleable`` / ``test_krylov``
(and the partition property tests): the real-valued suite structures, the
exact-arithmetic *dyadic* substitutions that make cross-executor bitwise
comparison meaningful, the random block structures, and the SPD systems the
Krylov layer consumes. Plain builders work without any optional dependency;
the hypothesis strategies at the bottom mirror them for the property-test
layer and are ``None`` when hypothesis is not installed (guard with
``pytest.importorskip("hypothesis")`` before using them).

Dyadic exactness contract
-------------------------
``dyadic`` keeps a matrix's sparsity but substitutes unit diagonals and
±0.25/±0.5 off-diagonal values. With shallow dependency depth (≤ 8 levels in
the canned ``EXACT_MATRICES``), every intermediate of a forward substitution
is exactly representable in float32: any two *correct* executions — across
kernels, executors, device counts — produce identical bits, so
``assert_array_equal`` really is bit-exactness and any schedule/masking/
exchange bug produces a loudly different answer. ``exactness_holds`` is the
self-check of that premise.
"""
from __future__ import annotations

import numpy as np

from repro import compat
from repro.core.blocking import build_blocks
from repro.sparse import suite
from repro.sparse.matrix import CSR, lower_triangular_from_coo, reference_solve


def mesh1():
    """Single-device mesh (the main test process keeps 1 CPU device)."""
    import jax

    return compat.make_mesh((1,), ("x",), devices=jax.devices()[:1])


# ---------------------------------------------------------------------------
# exact-arithmetic (dyadic) suites — bit-exactness across executors
# ---------------------------------------------------------------------------


def dyadic(a: CSR, seed: int = 0) -> CSR:
    """Same sparsity, exactly-representable values: unit diagonal, ±2^-k
    off-diagonals. With shallow (≤8 level) structures every intermediate fits
    float32 exactly, making cross-executor comparisons bit-meaningful."""
    rows = np.repeat(np.arange(a.n), np.diff(a.row_ptr))
    is_diag = a.col_idx == rows
    rng = np.random.default_rng(seed)
    signs = rng.choice(np.array([-0.5, -0.25, 0.25, 0.5], np.float32),
                       size=a.val.shape)
    val = np.where(is_diag, 1.0, signs).astype(np.float32)
    return CSR(n=a.n, row_ptr=a.row_ptr, col_idx=a.col_idx, val=val)


def dyadic_rhs(n: int, seed: int = 1, lo: int = -4, hi: int = 5) -> np.ndarray:
    """Small-integer rhs — exactly representable, pairs with ``dyadic``."""
    return np.random.default_rng(seed).integers(lo, hi, n).astype(np.float32)


def exactness_holds(a: CSR, b: np.ndarray) -> bool:
    """Self-check of the dyadic premise: the float32 solve equals the float64
    oracle bit-for-bit, i.e. no rounding happened anywhere."""
    x64 = reference_solve(a, b)
    return np.array_equal(x64.astype(np.float32).astype(np.float64), x64)


# suite-shaped structures: skewed level-size distribution and banded locality
EXACT_MATRICES = {
    "skewed": lambda: dyadic(suite.random_levelled(400, 8, 4.0, seed=6)),
    "banded": lambda: dyadic(
        suite.random_levelled(300, 8, 4.0, seed=7, locality=0.8)),
}


# ---------------------------------------------------------------------------
# real-valued solver regimes (scipy-oracle comparisons at float tolerance)
# ---------------------------------------------------------------------------

SOLVER_MATRICES = {
    "levelled": lambda: suite.random_levelled(400, 24, 4.0, seed=3),
    "chain": lambda: suite.chain(150),
    "grid": lambda: suite.grid2d_factor(18, seed=1),
    "parallel": lambda: suite.block_diagonal_parallel(300, 12, 3.0, seed=2),
    "two_level": lambda: suite.random_levelled(300, 2, 8.0, seed=4),
}


# ---------------------------------------------------------------------------
# degenerate structures (hardening regressions)
# ---------------------------------------------------------------------------


def empty_matrix() -> CSR:
    """n == 0: no rows, no levels, empty schedules."""
    return CSR(n=0, row_ptr=np.zeros(1, np.int64),
               col_idx=np.zeros(0, np.int32), val=np.zeros(0, np.float32))


def diagonal_matrix(n: int = 24, scale: float = 2.0) -> CSR:
    """Diagonal-only: one level, zero update tiles in every segment."""
    return CSR(n=n, row_ptr=np.arange(n + 1, dtype=np.int64),
               col_idx=np.arange(n, dtype=np.int32),
               val=np.full(n, scale, np.float32))


def single_entry_matrix(v: float = 3.0) -> CSR:
    """n == 1: a single diagonal entry — one row, one block, one level."""
    return CSR(n=1, row_ptr=np.array([0, 1], np.int64),
               col_idx=np.zeros(1, np.int32), val=np.array([v], np.float32))


# ---------------------------------------------------------------------------
# random block structures (partition-layer tests)
# ---------------------------------------------------------------------------


def random_triangular(n: int = 200, seed: int = 0, m: int = 600) -> CSR:
    """Random lower-triangular CSR from m coo draws (full diagonal added)."""
    rng = np.random.default_rng(seed)
    return lower_triangular_from_coo(
        n, rng.integers(0, n, m), rng.integers(0, n, m), rng=rng)


def random_blocks(n: int = 200, B: int = 8, seed: int = 0, m: int = 600):
    """Blocked structure of :func:`random_triangular` (partition-layer unit)."""
    return build_blocks(random_triangular(n, seed, m), B)


# ---------------------------------------------------------------------------
# SPD systems (Krylov-layer tests)
# ---------------------------------------------------------------------------


def spd_problem(side: int = 18, seed: int = 0):
    """grid2d_factor-derived SPD system (the paper's structured-grid regime):
    returns ``(a_lower, b, full_scipy_csc)``."""
    from repro.krylov import spd_lower_from_triangular, symmetric_full_csr
    from repro.sparse.matrix import to_scipy

    a = spd_lower_from_triangular(suite.grid2d_factor(side, seed=seed))
    b = np.random.default_rng(seed).uniform(-1, 1, a.n)
    full = to_scipy(symmetric_full_csr(a)).tocsc()
    return a, b, full


# ---------------------------------------------------------------------------
# hypothesis strategies (optional dependency — mirror the builders above)
# ---------------------------------------------------------------------------

try:
    from hypothesis import strategies as st
except ImportError:  # requirements-dev only; plain builders stay available
    st = None

if st is not None:

    @st.composite
    def triangular_problems(draw, max_n: int = 120, max_levels: int = 12):
        """Real-valued (a, b) problems over the levelled-suite structure
        space: varying size, depth, density and locality."""
        n = draw(st.integers(16, max_n))
        levels = draw(st.integers(1, min(max_levels, n)))
        avg_deps = draw(st.floats(1.0, 5.0))
        locality = draw(st.sampled_from([0.0, 0.8]))
        seed = draw(st.integers(0, 2**16))
        a = suite.random_levelled(n, levels, avg_deps, seed=seed,
                                  locality=locality)
        b = np.random.default_rng(seed ^ 0x5EED).uniform(-1, 1, a.n)
        return a, b

    @st.composite
    def dyadic_problems(draw, max_n: int = 160, max_levels: int = 8):
        """Exact-arithmetic (a, b) problems: dyadic values on shallow
        levelled structures + small-integer rhs, so bitwise cross-executor
        comparison is meaningful for every draw."""
        n = draw(st.integers(16, max_n))
        levels = draw(st.integers(1, min(max_levels, n)))
        avg_deps = draw(st.floats(1.0, 4.0))
        locality = draw(st.sampled_from([0.0, 0.8]))
        seed = draw(st.integers(0, 2**16))
        a = dyadic(suite.random_levelled(n, levels, avg_deps, seed=seed,
                                         locality=locality), seed=seed)
        b = dyadic_rhs(a.n, seed=seed ^ 0xD1AD)
        return a, b

    @st.composite
    def block_structures(draw, max_n: int = 240):
        """Random blocked structures for partition-layer properties."""
        n = draw(st.integers(16, max_n))
        B = draw(st.sampled_from([4, 8, 16]))
        m = draw(st.integers(0, 4 * n))
        seed = draw(st.integers(0, 1000))
        return random_blocks(n=n, B=B, seed=seed, m=m)
else:  # pragma: no cover - exercised only without requirements-dev
    triangular_problems = dyadic_problems = block_structures = None
