import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.analysis import in_degrees, level_sets, metrics
from repro.sparse.matrix import lower_triangular_from_coo


@st.composite
def csr_matrices(draw):
    n = draw(st.integers(8, 80))
    m = draw(st.integers(0, 4 * n))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return lower_triangular_from_coo(
        n, rng.integers(0, n, m), rng.integers(0, n, m), rng=rng
    )


@given(csr_matrices())
@settings(max_examples=40, deadline=None)
def test_level_schedule_is_valid_topological_order(a):
    """Every row's strictly-lower parents must sit in strictly earlier levels."""
    sched = level_sets(a)
    lvl = sched.level_of
    for i in range(a.n):
        for j in a.col_idx[a.row_ptr[i]:a.row_ptr[i + 1] - 1]:
            assert lvl[j] < lvl[i]
    # levels are tight: each row > level 0 has a parent exactly one level down
    for i in range(a.n):
        if lvl[i] > 0:
            parents = a.col_idx[a.row_ptr[i]:a.row_ptr[i + 1] - 1]
            assert (lvl[parents] == lvl[i] - 1).any()


@given(csr_matrices())
@settings(max_examples=40, deadline=None)
def test_in_degrees_match_structure(a):
    deg = in_degrees(a)
    assert np.array_equal(deg, np.diff(a.row_ptr) - 1)
    assert (deg >= 0).all()


def test_metrics_match_paper_definitions():
    rng = np.random.default_rng(0)
    a = lower_triangular_from_coo(64, rng.integers(0, 64, 128), rng.integers(0, 64, 128))
    m = metrics(a)
    assert m.dependency == a.nnz / a.n
    assert m.parallelism == a.n / m.n_levels
