"""Session API: analyse/factorize/solve lifecycle, typed options, auto mode."""
import numpy as np
import pytest

from repro.api import (
    Comm,
    KernelBackend,
    PlanOptions,
    Sched,
    SpTRSVContext,
    as_options,
    pattern_key,
)
from repro import compat
from repro.api.autotune import candidate_grid, estimate_plan_cost
from repro.core import DistributedSolver, SolverConfig, build_plan, refresh_plan
from repro.krylov import matvec_lower, solve_ic0_pcg, spd_lower_from_triangular
from repro.sparse import suite
from repro.sparse.matrix import CSR, reference_solve

MODES = [("zerocopy", "levelset"), ("zerocopy", "syncfree"),
         ("unified", "levelset"), ("unified", "syncfree")]


def _matrix(seed=0, n=400, levels=16):
    return suite.random_levelled(n, levels, 4.0, seed=seed)


def _revalued(a: CSR, scale=None) -> CSR:
    """Same pattern, different values (diagonal stays nonzero)."""
    if scale is None:
        scale = 1.0 + 0.25 * np.sin(np.arange(a.nnz))
    return CSR(n=a.n, row_ptr=a.row_ptr, col_idx=a.col_idx, val=a.val * scale)


# ---------------------------------------------------------------------------
# eager option validation (satellite: fail at the boundary, name the choices)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("field,value,expect", [
    ("comm", "bogus", "zerocopy"),
    ("sched", "wavefront", "levelset"),
    ("partition", "metis", "taskpool"),
    ("kernel", "cuda", "fused"),
])
def test_plan_options_invalid_choice_raises_eagerly(field, value, expect):
    with pytest.raises(ValueError, match=expect):
        PlanOptions(**{field: value})


@pytest.mark.parametrize("field,value,expect", [
    ("comm", "bogus", "zerocopy"),
    ("sched", "wavefront", "levelset"),
    ("partition", "metis", "taskpool"),
    ("kernel_backend", "cuda", "fused"),
])
def test_solver_config_invalid_choice_raises_eagerly(field, value, expect):
    with pytest.raises(ValueError, match=expect):
        SolverConfig(**{field: value})


def test_partition_cannot_be_auto():
    with pytest.raises(ValueError, match="partition"):
        PlanOptions(partition="auto")


def test_numeric_bounds_validated():
    with pytest.raises(ValueError, match="block_size"):
        PlanOptions(block_size=0)
    with pytest.raises(ValueError, match="rhs_hint"):
        SolverConfig(rhs_hint=0)


def test_options_config_round_trip():
    cfg = SolverConfig(block_size=16, comm="unified", sched="syncfree",
                       partition="malleable", kernel_backend="fused",
                       tasks_per_device=4, rhs_hint=8)
    opts = as_options(cfg)
    assert opts.comm == Comm.UNIFIED and opts.sched == Sched.SYNCFREE
    assert opts.kernel == KernelBackend.FUSED
    assert opts.to_config() == cfg
    # default kernel maps to None (platform default) and back
    assert PlanOptions().to_config().kernel_backend is None
    assert as_options(PlanOptions().to_config()).kernel == KernelBackend.DEFAULT


def test_auto_options_cannot_plan_unresolved():
    with pytest.raises(ValueError, match="auto"):
        PlanOptions.auto().to_config()


# ---------------------------------------------------------------------------
# context lifecycle
# ---------------------------------------------------------------------------


def test_analyse_once_solve_many():
    a = _matrix()
    b = np.random.default_rng(1).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    h = ctx.analyse(a)
    x = ctx.solve(h, b)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=0, atol=1e-5)
    for _ in range(3):
        ctx.solve(h, b)
    assert ctx.analyse(a) is h  # re-analyse is a cache hit
    st = ctx.stats()
    assert st["analyses"] == 1
    assert st["solves"] == 4
    assert st["solve_cache_hits"] == 3
    assert st["analysis_hits"] == 1
    assert 0 < st["cache_hit_rate"] < 1


def test_transpose_shares_analysis():
    a = _matrix()
    b = np.random.default_rng(2).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    h = ctx.analyse(a)
    xt = ctx.solve(h, b, transpose=True)
    import scipy.sparse.linalg as spla

    from repro.sparse.matrix import to_scipy

    expect = spla.spsolve_triangular(to_scipy(a).T.tocsr(), b, lower=False)
    np.testing.assert_allclose(xt, expect, rtol=0, atol=1e-4)
    assert ctx.stats()["analyses"] == 1  # L^T is an extension, not a re-analysis
    assert ctx.stats()["transpose_extensions"] == 1


def test_solve_accepts_matrix_directly():
    a = _matrix()
    b = np.random.default_rng(3).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    x = ctx.solve(a, b)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=0, atol=1e-5)


def test_multi_rhs_shape_cache_counts():
    a = _matrix()
    rng = np.random.default_rng(4)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    h = ctx.analyse(a)
    ctx.solve(h, rng.uniform(-1, 1, a.n))
    ctx.solve(h, rng.uniform(-1, 1, (a.n, 4)))  # new shape: miss
    ctx.solve(h, rng.uniform(-1, 1, (a.n, 4)))  # same shape: hit
    st = ctx.stats()
    assert st["solve_cache_misses"] == 2 and st["solve_cache_hits"] == 1


def test_tagged_handles_do_not_alias_values():
    a = _matrix()
    a2 = _revalued(a)
    b = np.random.default_rng(5).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    h1 = ctx.analyse(a)
    h2 = ctx.factorize(a2, tag="factor")
    assert h1 is not h2
    assert h1.symbolic is h2.symbolic  # ONE analysis for the pattern
    assert ctx.stats()["analyses"] == 1
    np.testing.assert_allclose(ctx.solve(h1, b), reference_solve(a, b),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(ctx.solve(h2, b), reference_solve(a2, b),
                               rtol=0, atol=1e-5)


def test_analyse_refreshes_stale_values_on_pattern_hit():
    a = _matrix()
    b = np.random.default_rng(6).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    ctx.solve(ctx.analyse(a), b)
    a2 = _revalued(a)
    x = ctx.solve(ctx.analyse(a2), b)  # same pattern, new values
    np.testing.assert_allclose(x, reference_solve(a2, b), rtol=0, atol=1e-5)
    assert ctx.stats()["analyses"] == 1


# ---------------------------------------------------------------------------
# numeric refresh (satellite: bit-identical to a fresh build across modes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comm,sched", MODES)
def test_refresh_bit_identical_to_fresh_build(comm, sched):
    a = _matrix()
    a2 = _revalued(a)
    cfg = SolverConfig(block_size=16, comm=comm, sched=sched)
    refreshed = refresh_plan(build_plan(a, 1, cfg), a2)
    fresh = build_plan(a2, 1, cfg)
    assert np.array_equal(refreshed.diag, fresh.diag)
    assert np.array_equal(refreshed.tiles, fresh.tiles)
    assert np.array_equal(refreshed.solve_rows, fresh.solve_rows)
    # and the solve through the refreshed executor is bit-identical too
    b = np.random.default_rng(7).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=cfg)
    h = ctx.analyse(a)
    ctx.solve(h, b)  # compile on a's values
    ctx.factorize(a2, h)
    assert np.array_equal(ctx.solve(h, b),
                          DistributedSolver(fresh, ctx.mesh).solve(b))


def test_refresh_transpose_plan():
    a = _matrix()
    a2 = _revalued(a)
    cfg = SolverConfig(block_size=16)
    ctx = SpTRSVContext(options=cfg)
    h = ctx.analyse(a)
    ctx.solve(h, np.ones(a.n), transpose=True)  # build + compile transpose
    ctx.factorize(a2, h)
    fresh_t = build_plan(a2, 1, cfg, transpose=True)
    assert np.array_equal(h.tplan.diag, fresh_t.diag)
    assert np.array_equal(h.tplan.tiles, fresh_t.tiles)


def test_factorize_rejects_different_pattern():
    a = _matrix(seed=0)
    other = _matrix(seed=3)
    assert pattern_key(a) != pattern_key(other)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    h = ctx.analyse(a)
    with pytest.raises(ValueError, match="pattern"):
        ctx.factorize(other, h)


def test_refresh_plan_rejects_different_pattern():
    a = _matrix(seed=0)
    plan = build_plan(a, 1, SolverConfig(block_size=16))
    with pytest.raises(ValueError, match="pattern"):
        refresh_plan(plan, _matrix(seed=3))


# ---------------------------------------------------------------------------
# auto mode
# ---------------------------------------------------------------------------


def test_candidate_grid_dimensions():
    # sched axis: levelset + dagpart + syncfree;
    # kernel axis: platform default + fused + fused_streamed
    assert len(candidate_grid(PlanOptions.auto(probe_solves=0), 4)) == 3 * 2 * 3
    assert len(candidate_grid(PlanOptions.auto(probe_solves=0), 1)) == 3 * 1 * 3
    only_kernel = PlanOptions(kernel="auto")
    assert len(candidate_grid(only_kernel, 4)) == 3
    fixed = PlanOptions()
    assert candidate_grid(fixed, 4) == [("levelset", "zerocopy", "default")]


def test_auto_dedups_byte_identical_candidates(monkeypatch):
    """tune() never scores/probes the same compiled program twice: syncfree
    fused_streamed == fused by definition, so only the fused combo survives
    (and on plans past the VMEM limit the levelset pair collapses too)."""
    from repro.api.autotune import tune

    a = _matrix()
    opts = PlanOptions(block_size=16, sched="syncfree", kernel="auto",
                       probe_solves=0)
    _, _, decision, _ = tune(a, opts, compat.make_mesh((1,), ("x",)))
    kernels = {k for (_, _, k) in decision.scores}
    assert "fused_streamed" not in kernels
    assert "fused" in kernels
    # levelset keeps both variants while the resident store fits VMEM...
    opts_lv = PlanOptions(block_size=16, sched="levelset", kernel="auto",
                          probe_solves=0)
    _, _, dec_lv, _ = tune(a, opts_lv, compat.make_mesh((1,), ("x",)))
    assert {"fused", "fused_streamed"} <= {k for (_, _, k) in dec_lv.scores}
    # ...and collapses them once plain fused would auto-stream anyway
    monkeypatch.setenv("REPRO_STREAM_VMEM_LIMIT", "1")
    _, _, dec_small, _ = tune(a, opts_lv, compat.make_mesh((1,), ("x",)))
    assert "fused_streamed" not in {k for (_, _, k) in dec_small.scores}


def test_auto_modelled_selection_records_decision():
    a = _matrix()
    b = np.random.default_rng(8).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions.auto(block_size=16, probe_solves=0))
    h = ctx.analyse(a)
    assert h.auto is not None and h.auto.mode == "modelled"
    sched, comm, kernel = h.auto.chosen
    assert sched in ("levelset", "syncfree") and comm == "zerocopy"
    assert h.auto.scores[h.auto.chosen] == min(h.auto.scores.values())
    assert h.config.sched == sched and h.config.comm == comm
    np.testing.assert_allclose(ctx.solve(h, b), reference_solve(a, b),
                               rtol=0, atol=1e-5)
    ds = ctx.dispatch_stats(h)
    assert ds["auto"]["chosen"] == h.auto.chosen
    assert ctx.stats()["analyses"] == 1  # candidates shared one partition


def test_auto_probed_selection_picks_measured_min():
    a = _matrix(n=200, levels=8)
    opts = PlanOptions(block_size=16, kernel="auto", probe_solves=2)
    ctx = SpTRSVContext(options=opts)
    h = ctx.analyse(a)
    assert h.auto.mode == "probed"
    assert h.auto.probe_us, "probed mode must record measurements"
    assert h.auto.probe_us[h.auto.chosen] == min(h.auto.probe_us.values())
    assert h.auto.probe_overhead_us > 0
    # the probed winner's executor is reused, not recompiled
    assert False in h.solvers
    b = np.random.default_rng(9).uniform(-1, 1, a.n)
    np.testing.assert_allclose(ctx.solve(h, b), reference_solve(a, b),
                               rtol=0, atol=1e-5)


def test_estimate_plan_cost_orders_dense_vs_bucketed_syncfree():
    a = _matrix()
    dense = build_plan(a, 1, SolverConfig(block_size=16, sched="syncfree"))
    bucketed = build_plan(a, 1, SolverConfig(block_size=16, sched="syncfree",
                                             kernel_backend="fused"))
    # the frontier-bucketed executor never models worse than the dense scan
    assert estimate_plan_cost(bucketed) <= estimate_plan_cost(dense)


# ---------------------------------------------------------------------------
# krylov as a context client (acceptance: one analysis per pattern)
# ---------------------------------------------------------------------------


def test_solve_ic0_pcg_single_analysis_per_pattern():
    a = spd_lower_from_triangular(suite.grid2d_factor(20, seed=0))
    b = np.random.default_rng(10).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    res = solve_ic0_pcg(a, b, context=ctx, tol=1e-8)
    np.testing.assert_allclose(matvec_lower(a, res.x), b, rtol=0, atol=1e-5)
    st = ctx.stats()
    assert st["analyses"] == 1, st  # SpMV + L + L^T: one partition/analysis
    assert res.info["forward"].n_solves >= res.n_iters > 0
    # a second solve on the same pattern re-analyses nothing
    res2 = solve_ic0_pcg(a, b, context=ctx, tol=1e-8)
    np.testing.assert_allclose(matvec_lower(a, res2.x), b, rtol=0, atol=1e-5)
    assert ctx.stats()["analyses"] == 1
    assert np.array_equal(res.x, res2.x)


def test_ilu0_refresh_rejects_pattern_change():
    from repro.krylov import ILU0Preconditioner, symmetric_full_csr

    a = spd_lower_from_triangular(suite.grid2d_factor(12, seed=2))
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    pre = ILU0Preconditioner(ctx, symmetric_full_csr(a))
    other = spd_lower_from_triangular(suite.grid2d_factor(13, seed=2))
    with pytest.raises(ValueError, match="pattern"):
        pre.refresh(symmetric_full_csr(other))
    # same-pattern refresh stays silent and re-analyses nothing
    n_before = ctx.stats()["analyses"]
    pre.refresh(symmetric_full_csr(_revalued(a, scale=1.3)))
    assert ctx.stats()["analyses"] == n_before


def test_preconditioner_refresh_no_reanalysis():
    a = spd_lower_from_triangular(suite.grid2d_factor(16, seed=1))
    b = np.random.default_rng(11).uniform(-1, 1, a.n)
    ctx = SpTRSVContext(options=PlanOptions(block_size=16))
    res = solve_ic0_pcg(a, b, context=ctx, tol=1e-8)
    pre = res.info["preconditioner"]
    a2 = _revalued(a, scale=1.2)
    pre.refresh(a2)
    assert ctx.stats()["analyses"] == 1
    res2 = solve_ic0_pcg(a2, b, context=ctx, tol=1e-8)
    np.testing.assert_allclose(matvec_lower(a2, res2.x), b, rtol=0, atol=1e-5)
    assert ctx.stats()["analyses"] == 1
