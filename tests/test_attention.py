"""Flash attention vs einsum reference: causal, sliding window, softcap, GQA."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.attention import _flash, _repeat_kv

KEY = jax.random.PRNGKey(0)


def _ref(q, k, v, *, causal, window, cap, hd):
    scores = jnp.einsum("bshd,bthd->bhst", q, k) * hd ** -0.5
    if cap:
        scores = jnp.tanh(scores / cap) * cap
    S, T = q.shape[1], k.shape[1]
    pos_q, pos_k = jnp.arange(S), jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = pos_k[None] <= pos_q[:, None]
    if window:
        mask &= pos_k[None] > pos_q[:, None] - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    return jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(scores, -1), v)


@pytest.mark.parametrize("window", [0, 700])
@pytest.mark.parametrize("cap", [0.0, 30.0])
@pytest.mark.parametrize("differentiable", [False, True])
def test_flash_matches_reference(window, cap, differentiable):
    cfg = dataclasses.replace(get_reduced("gemma2-2b"), softcap=cap)
    B, S, H, hd = 2, 2048, 4, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    out = _flash(q, k, v, cfg, causal=True, window=window, chunk=512,
                 differentiable=differentiable)
    ref = _ref(q, k, v, causal=True, window=window, cap=cap, hd=hd)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_gradients_match_reference():
    cfg = get_reduced("llama3.2-1b")
    B, S, H, hd = 1, 2048, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))

    def f_flash(q):
        return jnp.sum(_flash(q, k, v, cfg, causal=True, window=0, chunk=512,
                              differentiable=True) ** 2)

    def f_ref(q):
        return jnp.sum(_ref(q, k, v, causal=True, window=0, cap=0, hd=hd) ** 2)

    g1, g2 = jax.grad(f_flash)(q), jax.grad(f_ref)(q)
    np.testing.assert_allclose(g1, g2, rtol=5e-4, atol=5e-4)


def test_repeat_kv_expands_heads():
    k = jax.random.normal(KEY, (2, 8, 3, 4))
    kr = _repeat_kv(k, 2)
    assert kr.shape == (2, 8, 6, 4)
    np.testing.assert_array_equal(kr[:, :, 0], kr[:, :, 1])
    np.testing.assert_array_equal(kr[:, :, 2], kr[:, :, 3])
