import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.blocking import build_blocks, pad_rhs, unpad_x
from repro.sparse.matrix import lower_triangular_from_coo, to_scipy


def _dense_from_blocks(bs):
    n_pad = bs.nb * bs.B
    dense = np.zeros((n_pad, n_pad), np.float64)
    for bi in range(bs.nb):
        dense[bi * bs.B:(bi + 1) * bs.B, bi * bs.B:(bi + 1) * bs.B] = bs.diag[bi]
    for t in range(bs.n_tiles):
        r, c = bs.off_rows[t], bs.off_cols[t]
        dense[r * bs.B:(r + 1) * bs.B, c * bs.B:(c + 1) * bs.B] = bs.off_tiles[t]
    return dense


@given(st.integers(8, 70), st.integers(2, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_block_reconstruction(n, B, seed):
    rng = np.random.default_rng(seed)
    m = 4 * n
    a = lower_triangular_from_coo(n, rng.integers(0, n, m), rng.integers(0, n, m), rng=rng)
    bs = build_blocks(a, B)
    dense = _dense_from_blocks(bs)
    ref = to_scipy(a).toarray()
    np.testing.assert_allclose(dense[: a.n, : a.n], ref, rtol=1e-6, atol=1e-6)
    # padding rows are identity (inert under solve)
    for i in range(a.n, bs.nb * bs.B):
        assert dense[i, i] == 1.0
        assert np.count_nonzero(dense[i, :]) == 1


@given(st.integers(8, 70), st.integers(2, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_block_levels_valid(n, B, seed):
    rng = np.random.default_rng(seed)
    a = lower_triangular_from_coo(
        n, rng.integers(0, n, 3 * n), rng.integers(0, n, 3 * n), rng=rng
    )
    bs = build_blocks(a, B)
    lvl = bs.block_level
    for t in range(bs.n_tiles):
        assert lvl[bs.off_cols[t]] < lvl[bs.off_rows[t]]
    assert np.array_equal(bs.block_indeg, np.bincount(bs.off_rows, minlength=bs.nb))


def test_pad_roundtrip():
    rng = np.random.default_rng(0)
    a = lower_triangular_from_coo(37, rng.integers(0, 37, 60), rng.integers(0, 37, 60))
    bs = build_blocks(a, 8)
    b = rng.uniform(-1, 1, 37)
    np.testing.assert_allclose(unpad_x(pad_rhs(b, bs), bs), b.astype(np.float32), rtol=1e-6)
