"""Perf-trajectory gate: N-run window medians and the fused-vs-switch ratio."""
import json

import pytest

from benchmarks.compare import (
    coalesce_wins,
    compare,
    compare_fused,
    fused_ratios,
    gate_coalesce_win,
    load_provenance,
    load_rows,
    main,
    provenance_note,
)


def rows(**kv):
    return {k: float(v) for k, v in kv.items()}


def test_window_median_is_baseline():
    window = [rows(a=100.0), rows(a=1000.0), rows(a=110.0)]
    # median 110 absorbs the one noisy 1000us run; 120 is within 25%
    regs, imps, skipped, zeroed = compare(window, rows(a=120.0), 0.25)
    assert not regs and not imps
    regs, _, _, _ = compare(window, rows(a=200.0), 0.25)
    assert [r[0] for r in regs] == ["a"]
    assert regs[0][1] == pytest.approx(110.0)  # baseline = window median


def test_single_predecessor_degenerates_to_pairwise():
    regs, imps, _, _ = compare([rows(a=100.0)], rows(a=130.0), 0.25)
    assert [r[0] for r in regs] == ["a"]
    regs, imps, _, _ = compare([rows(a=100.0)], rows(a=70.0), 0.25)
    assert not regs and [i[0] for i in imps] == ["a"]


def test_noise_floor_and_zeroed_rows():
    window = [rows(tiny=10.0, broken=500.0)]
    regs, _, skipped, zeroed = compare(
        window, rows(tiny=40.0, broken=0.0), 0.25)
    assert not regs
    assert "tiny" in skipped  # both below the 50us noise floor
    assert zeroed == [("broken", 500.0)]


def test_row_only_in_window_or_new_never_fails():
    regs, _, _, _ = compare([rows(old=100.0)], rows(new=100.0), 0.25)
    assert not regs


def test_fused_ratio_extraction():
    r = fused_ratios({"kernel/dc2/fused": 200.0, "kernel/dc2/switch": 100.0,
                      "kernel/x/fused": 10.0, "kernel/x/switch": 10.0,
                      "fig9/dc2/tasks4": 100.0})
    assert r == {"dc2": 2.0}  # sub-noise-floor pair and non-kernel rows ignored


def test_fused_gate_regression():
    window = [
        {"kernel/dc2/fused": 150.0, "kernel/dc2/switch": 100.0},
        {"kernel/dc2/fused": 170.0, "kernel/dc2/switch": 100.0},
    ]
    ok = {"kernel/dc2/fused": 180.0, "kernel/dc2/switch": 100.0}
    assert compare_fused(window, ok, 0.25) == []
    # both rows got slower proportionally: per-row gate may pass, the RATIO
    # gate still catches the megakernel's advantage eroding
    bad = {"kernel/dc2/fused": 260.0, "kernel/dc2/switch": 100.0}
    regs = compare_fused(window, bad, 0.25)
    assert [m for m, _, _ in regs] == ["dc2"]
    base, ratio = regs[0][1], regs[0][2]
    assert base == pytest.approx(1.6) and ratio == pytest.approx(2.6)


def test_metadata_keys_excluded_from_gating(tmp_path):
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({
        "a": {"us_per_call": 100.0, "derived": ""},
        "_provenance": {"jax_version": "0.4.37", "device_count": 1},
        "_metrics": {"session.solves": 3},
    }))
    assert load_rows(str(p)) == {"a": 100.0}
    assert load_provenance(str(p))["jax_version"] == "0.4.37"
    # pre-provenance bench files (older artifacts) load cleanly too
    q = tmp_path / "old.json"
    q.write_text(json.dumps({"a": {"us_per_call": 90.0}}))
    assert load_rows(str(q)) == {"a": 90.0}
    assert load_provenance(str(q)) == {}


def test_provenance_note_surfaces_drift(tmp_path):
    def dump(name, prov):
        p = tmp_path / name
        p.write_text(json.dumps({"a": {"us_per_call": 100.0},
                                 "_provenance": prov}))
        return str(p)

    old = dump("old.json", {"jax_version": "0.4.37", "device_count": 4,
                            "platform": "cpu"})
    same = dump("same.json", {"jax_version": "0.4.37", "device_count": 4,
                              "platform": "cpu"})
    drift = dump("drift.json", {"jax_version": "0.4.38", "device_count": 8,
                                "platform": "cpu"})
    assert provenance_note(old, same) == ""
    note = provenance_note(old, drift)
    assert "jax_version" in note and "'0.4.37' -> '0.4.38'" in note
    assert "device_count: 4 -> 8" in note
    # either side missing provenance: no note, never an error
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps({"a": {"us_per_call": 100.0}}))
    assert provenance_note(str(bare), drift) == ""


def dump_service(tmp_path, name, win, extra=None):
    p = tmp_path / name
    data = {"service/hot": {"us_per_call": 300.0,
                            "derived": f"req_per_s=2000;coalesce_width=8.0;"
                                       f"hit_rate=1.00;coalesce_win={win}"},
            "service/hot/onebyone": {"us_per_call": 300.0 * win,
                                     "derived": "req_per_s=500"}}
    data.update(extra or {})
    p.write_text(json.dumps(data))
    return str(p)


def test_coalesce_win_extraction_and_gate(tmp_path):
    good = dump_service(tmp_path, "good.json", 4.5, extra={
        # non-service and malformed rows never participate
        "kernel/m/fused": {"us_per_call": 100.0, "derived": ""},
        "service/odd": {"us_per_call": 10.0, "derived": "req_per_s=1"},
    })
    assert coalesce_wins(good) == {"hot": 4.5}
    assert gate_coalesce_win(good, 1.0) == []
    bad = dump_service(tmp_path, "bad.json", 0.8)
    assert gate_coalesce_win(bad, 1.0) == [("hot", 0.8)]
    # an unknown mix name is reported by extraction but never gated
    exotic = dump_service(tmp_path, "exotic.json", 4.0)
    data = json.loads(open(exotic).read())
    data["service/adversarial"] = {"us_per_call": 10.0,
                                   "derived": "coalesce_win=0.1"}
    open(exotic, "w").write(json.dumps(data))
    assert gate_coalesce_win(exotic, 1.0) == []


def test_cli_coalesce_win_exit_code(tmp_path):
    prev = dump_service(tmp_path, "prev.json", 4.0)
    good = dump_service(tmp_path, "new_good.json", 3.5)
    assert main([prev, good]) == 0
    bad = dump_service(tmp_path, "new_bad.json", 0.9)
    assert main([prev, bad]) == 1
    # the threshold is a knob: demanding more than the run delivers fails
    assert main([prev, good, "--min-coalesce-win", "10.0"]) == 1


def test_cli_window_and_exit_codes(tmp_path):
    def dump(name, data):
        p = tmp_path / name
        p.write_text(json.dumps(
            {k: {"us_per_call": v, "derived": ""} for k, v in data.items()}))
        return str(p)

    prev1 = dump("p1.json", {"a": 100.0, "kernel/m/fused": 150.0,
                             "kernel/m/switch": 100.0})
    prev2 = dump("p2.json", {"a": 120.0, "kernel/m/fused": 160.0,
                             "kernel/m/switch": 100.0})
    good = dump("good.json", {"a": 115.0, "kernel/m/fused": 155.0,
                              "kernel/m/switch": 100.0})
    assert main([prev1, prev2, good]) == 0
    slow = dump("slow.json", {"a": 400.0, "kernel/m/fused": 155.0,
                              "kernel/m/switch": 100.0})
    assert main([prev1, prev2, slow]) == 1
    ratio_bad = dump("ratio.json", {"a": 115.0, "kernel/m/fused": 300.0,
                                    "kernel/m/switch": 100.0})
    assert main([prev1, prev2, ratio_bad]) == 1
