"""Cost-model calibration + multi-RHS-aware block_row_cost."""
import numpy as np
import pytest

from repro.core import costmodel
from repro.core.blocking import build_blocks
from repro.core.partition import (
    DEFAULT_COST_WEIGHTS, block_row_cost, cut_stats, make_partition,
)
from repro.sparse.suite import random_levelled


def _blocks(B=16):
    return build_blocks(random_levelled(400, 10, 4.0, seed=3), B)


def test_default_weights_reproduce_analytic_model():
    """weights=(1,1,1), R=1 must equal the historical 1 + 2·col_tiles."""
    bs = _blocks()
    col_tiles = np.bincount(bs.off_cols, minlength=bs.nb)
    np.testing.assert_allclose(block_row_cost(bs), 1.0 + 2.0 * col_tiles)
    np.testing.assert_allclose(
        block_row_cost(bs, weights=DEFAULT_COST_WEIGHTS, R=1),
        1.0 + 2.0 * col_tiles)


def test_multirhs_cost_amortizes_tile_mem():
    """Panels scale the solve and flop terms by R but not the tile-load term,
    so tile-heavy rows get relatively CHEAPER as R grows — the GEMM
    amortization the partitioner should reward."""
    bs = _blocks()
    col_tiles = np.bincount(bs.off_cols, minlength=bs.nb)
    c1 = block_row_cost(bs, R=1)
    c4 = block_row_cost(bs, R=4)
    np.testing.assert_allclose(c4, 4.0 + (1.0 + 4.0) * col_tiles)
    # per-RHS cost of tile-heavy rows drops relative to tile-free rows
    heavy = col_tiles.argmax()
    light = col_tiles.argmin()
    assert col_tiles[heavy] > col_tiles[light]
    ratio1 = c1[heavy] / c1[light]
    ratio4 = (c4[heavy] / 4) / (c4[light] / 4)
    assert ratio4 < ratio1


@pytest.mark.parametrize("backend", [None, "pallas", "fused"])
def test_calibrate_weights_well_formed(backend):
    w = costmodel.calibrate_weights(16, backend=backend)
    assert len(w) == 3
    assert w[0] == 1.0
    assert all(np.isfinite(v) and v >= 0.0 for v in w)
    # cached: identical object on repeat call
    assert costmodel.calibrate_weights(16, backend=backend) is w


def test_calibrated_weights_thread_into_malleable():
    bs = _blocks()
    w = costmodel.calibrate_weights(16, backend=None)
    part = make_partition(bs, 4, "malleable", 8, cost_weights=w, cost_R=4)
    assert part.owner.min() >= 0 and part.owner.max() < 4
    # every block row assigned, partition is still balanced per level
    cs = cut_stats(bs, part)
    assert cs.level_imbalance >= 1.0


def test_build_plan_calibrate_cost_flag():
    from repro.core import SolverConfig, build_plan

    a = random_levelled(300, 8, 3.0, seed=4)
    plan = build_plan(a, 2, SolverConfig(
        block_size=16, partition="malleable", calibrate_cost=True, rhs_hint=4))
    assert plan.part.owner.shape == (plan.bs.nb,)
    assert set(np.unique(plan.part.owner)) <= {0, 1}
