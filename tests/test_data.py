import numpy as np

from repro.configs import get_reduced
from repro.data import SyntheticLM


def test_batches_are_deterministic_and_step_dependent():
    cfg = get_reduced("llama3.2-1b")
    d = SyntheticLM(cfg, global_batch=4, seq_len=16, seed=1)
    a, b = d.batch(3), d.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(d.batch(3)["tokens"], d.batch(4)["tokens"])


def test_host_sharding_partitions_global_batch():
    cfg = get_reduced("llama3.2-1b")
    d = SyntheticLM(cfg, global_batch=8, seq_len=8)
    full = d.batch(0)["tokens"]
    parts = [d.batch(0, host_index=i, host_count=4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_labels_are_shifted_tokens():
    cfg = get_reduced("llama3.2-1b")
    b = SyntheticLM(cfg, 2, 16).batch(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    # tokens/labels come from one (B, S+1) draw: label[t] == token[t+1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_modality_stub_batches():
    cfg = get_reduced("internvl2-1b")
    b = SyntheticLM(cfg, 2, 16).batch(0)
    assert "embeds" in b and b["embeds"].shape == (2, 16, cfg.d_model)
    cfg = get_reduced("seamless-m4t-medium")
    b = SyntheticLM(cfg, 2, 16).batch(0)
    assert b["enc_embeds"].shape == (2, cfg.enc_seq, cfg.d_model)
