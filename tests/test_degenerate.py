"""Degenerate-input hardening: empty levels, zero-tile segments, single-row
blocks — every executor backend must handle them without special-casing by
the caller.

Regressions pinned here:
* n == 0 used to crash every executor at trace time — the T == 0 bucket was
  ``(1, 0, 0)``, so the (never-executed) superstep branch indexed the 0-row
  ``lvl_off`` table; the fused kernel additionally sliced the empty level
  tables. Now the empty bucket is all-zero and ``superstep_call`` pads empty
  tables to one inert row.
"""
import numpy as np
import pytest

import strategies
from strategies import mesh1 as _mesh1
from repro.core import DistributedSolver, SolverConfig, build_plan, dispatch_stats
from repro.core.solver import fused_segments, level_widths
from repro.sparse.matrix import reference_solve

BACKENDS = ("reference", "pallas", "fused", "fused_streamed")


@pytest.mark.parametrize("kernel", BACKENDS)
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_empty_matrix_solves(kernel, sched):
    """n == 0: no levels, no tiles — the solve returns an empty vector."""
    a = strategies.empty_matrix()
    plan = build_plan(a, 1, SolverConfig(block_size=8, sched=sched,
                                         kernel_backend=kernel))
    assert plan.n_levels == 0 and plan.bs.nb == 0
    segs = fused_segments(plan)
    assert segs.shape == (0, 2)
    assert level_widths(plan).shape == (0, 3)
    assert plan.comm_bytes_per_solve == 0
    ds = dispatch_stats(plan)
    assert ds["fused_launches"] == 0 and ds["switch_dispatches"] == 0
    x = DistributedSolver(plan, _mesh1()).solve(np.zeros(0))
    assert x.shape == (0,)


@pytest.mark.parametrize("kernel", BACKENDS)
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_zero_tile_segments(kernel, sched):
    """Diagonal-only matrix: one level whose update schedule is empty — the
    fused segment has zero tiles and the streamed variant must not DMA any."""
    a = strategies.diagonal_matrix(n=24, scale=2.0)
    b = np.arange(1.0, 25.0)
    plan = build_plan(a, 1, SolverConfig(block_size=8, sched=sched,
                                         kernel_backend=kernel))
    if plan.n_levels:
        assert (level_widths(plan)[:, 1] == 0).all()  # no update tiles anywhere
    x = DistributedSolver(plan, _mesh1()).solve(b)
    np.testing.assert_allclose(x, b / 2.0, rtol=0, atol=0)


@pytest.mark.parametrize("kernel", BACKENDS)
def test_single_row_block(kernel):
    """n < block_size: the whole matrix is one block row, one level, and the
    fused path runs exactly one launch with a single-row schedule."""
    a = strategies.random_triangular(n=5, seed=0, m=8)
    b = np.arange(1.0, 6.0)
    plan = build_plan(a, 1, SolverConfig(block_size=8, kernel_backend=kernel))
    assert plan.bs.nb == 1 and plan.n_levels == 1
    assert len(fused_segments(plan)) == 1
    x = DistributedSolver(plan, _mesh1()).solve(b)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kernel", BACKENDS)
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_single_entry_matrix(kernel, sched):
    """n == 1: one row, one diagonal entry, no updates."""
    a = strategies.single_entry_matrix(v=3.0)
    plan = build_plan(a, 1, SolverConfig(block_size=8, sched=sched,
                                         kernel_backend=kernel))
    x = DistributedSolver(plan, _mesh1()).solve(np.array([6.0]))
    np.testing.assert_allclose(x, [2.0], rtol=0, atol=0)


def test_empty_matrix_multirhs_fused():
    """(0, R) panels through the fused paths (multi-RHS kernel arithmetic)."""
    a = strategies.empty_matrix()
    for kernel in ("fused", "fused_streamed"):
        plan = build_plan(a, 1, SolverConfig(block_size=8, kernel_backend=kernel))
        x = DistributedSolver(plan, _mesh1()).solve(np.zeros((0, 3)))
        assert x.shape == (0, 3)


def test_zero_tile_segment_multidevice_plan():
    """A multi-device plan with an empty cut fuses the whole solve into one
    launch even when some levels schedule zero tiles on some device."""
    from repro.sparse import suite

    a = suite.block_diagonal_parallel(512, 8, 3.0, seed=2)
    plan = build_plan(a, 8, SolverConfig(block_size=16, partition="contiguous",
                                         kernel_backend="fused_streamed"))
    assert plan.n_boundary_rows == 0
    assert len(fused_segments(plan)) == 1
    assert dispatch_stats(plan)["exchanges"] == 0
