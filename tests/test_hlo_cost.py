"""Loop-aware HLO cost analyzer: exactness on known loop structures."""
import functools

import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.launch.hlo_cost import analyze

A = jax.ShapeDtypeStruct((128, 128), jnp.float32)
MM = 2 * 128**3  # flops of one 128^3 matmul


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze(c.as_text())["flops"]


@pytest.mark.parametrize("n", [1, 4, 16])
def test_scan_trip_count_multiplied(n):
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=n)
        return y

    assert abs(_flops(f, A) / (n * MM) - 1) < 0.01


def test_nested_scans():
    def f(x):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda ci, _: (ci @ ci, None), c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    assert abs(_flops(f, A) / (15 * MM) - 1) < 0.01


def test_remat_grad_counts_recompute():
    """fwd(6) + remat recompute(6) + bwd dgemm(2x6) = 24 matmul equivalents."""
    def train(x):
        def loss(w):
            y, _ = jax.lax.scan(
                jax.checkpoint(lambda c, _: (jnp.tanh(c @ w), None)),
                x, None, length=6)
            return jnp.sum(y)
        return jax.grad(loss)(jnp.eye(128))

    assert abs(_flops(train, A) / (24 * MM) - 1) < 0.01


def test_collectives_in_loops():
    mesh = compat.make_mesh((1,), ("x",))

    def h(x):
        y, _ = jax.lax.scan(lambda c, _: (jax.lax.psum(c, "x"), None),
                            x, None, length=7)
        return y

    hs = jax.jit(compat.shard_map(h, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                                  out_specs=jax.sharding.PartitionSpec()))
    c = hs.lower(jax.ShapeDtypeStruct((128,), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["collective_bytes"]["all-reduce"] == 7 * 128 * 4
