"""Per-kernel allclose vs the pure-jnp oracles, interpret mode, shape/dtype sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.block_spmv import block_gemm, block_gemv, block_gemv_grouped
from repro.kernels.block_trsv import block_trsm, block_trsv


def _tri(k, B, dtype, seed=0):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.uniform(-1, 1, (k, B, B))).astype(dtype)
    L[:, np.arange(B), np.arange(B)] = 2.0 + rng.uniform(0, 1, (k, B))
    r = rng.uniform(-1, 1, (k, B)).astype(dtype)
    return jnp.asarray(L), jnp.asarray(r)


@pytest.mark.parametrize("B", [8, 16, 32, 64])
@pytest.mark.parametrize("k", [1, 3, 17])
def test_trsv_rowsweep_matches_oracle(B, k):
    L, r = _tri(k, B, np.float32, seed=B * 100 + k)
    out = block_trsv(L, r, algorithm="rowsweep", interpret=True)
    np.testing.assert_allclose(out, ref.block_trsv_ref(L, r), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,panel", [(16, 8), (32, 8), (64, 16)])
def test_trsv_panel_matches_oracle(B, panel):
    L, r = _tri(5, B, np.float32, seed=B)
    out = block_trsv(L, r, algorithm="panel", panel=panel, interpret=True)
    np.testing.assert_allclose(out, ref.block_trsv_ref(L, r), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize("B", [8, 32, 128])
@pytest.mark.parametrize("m", [1, 5, 13])
def test_gemv_matches_oracle(B, m, dtype):
    rng = np.random.default_rng(B + m)
    T = jnp.asarray(rng.uniform(-1, 1, (m, B, B)).astype(dtype))
    x = jnp.asarray(rng.uniform(-1, 1, (m, B)).astype(dtype))
    out = block_gemv(T, x, interpret=True)
    np.testing.assert_allclose(out, ref.block_gemv_ref(T, x), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("group", [2, 4, 8])
def test_gemv_grouped_matches_oracle(group):
    rng = np.random.default_rng(group)
    m, B = 11, 16  # deliberately not a multiple of group (exercises padding)
    T = jnp.asarray(rng.uniform(-1, 1, (m, B, B)).astype(np.float32))
    x = jnp.asarray(rng.uniform(-1, 1, (m, B)).astype(np.float32))
    out = block_gemv_grouped(T, x, group=group, interpret=True)
    np.testing.assert_allclose(out, ref.block_gemv_ref(T, x), rtol=2e-5, atol=2e-5)


def test_trsv_solves_the_system():
    L, r = _tri(4, 32, np.float32)
    x = block_trsv(L, r, interpret=True)
    np.testing.assert_allclose(
        jnp.einsum("kij,kj->ki", L, x), r, rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# multi-RHS panels: one kernel launch serves R systems
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,k,R", [(8, 1, 2), (16, 3, 4), (32, 5, 8)])
def test_trsm_matches_oracle(B, k, R):
    L, _ = _tri(k, B, np.float32, seed=B + R)
    r = jnp.asarray(np.random.default_rng(R).uniform(-1, 1, (k, B, R)).astype(np.float32))
    out = block_trsm(L, r, interpret=True)
    np.testing.assert_allclose(out, ref.block_trsv_ref(L, r), rtol=2e-5, atol=2e-5)


def test_trsm_columns_equal_independent_trsv():
    """Panel solve must be exactly R stacked single-RHS solves."""
    k, B, R = 4, 16, 3
    L, _ = _tri(k, B, np.float32, seed=9)
    r = jnp.asarray(np.random.default_rng(9).uniform(-1, 1, (k, B, R)).astype(np.float32))
    panel = block_trsm(L, r, interpret=True)
    for j in range(R):
        single = block_trsv(L, r[..., j], interpret=True)
        np.testing.assert_allclose(panel[..., j], single, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,m,R", [(8, 1, 2), (16, 7, 4), (32, 4, 5)])
def test_gemm_matches_oracle(B, m, R):
    rng = np.random.default_rng(B + m + R)
    T = jnp.asarray(rng.uniform(-1, 1, (m, B, B)).astype(np.float32))
    x = jnp.asarray(rng.uniform(-1, 1, (m, B, R)).astype(np.float32))
    out = block_gemm(T, x, interpret=True)
    np.testing.assert_allclose(out, ref.block_gemv_ref(T, x), rtol=2e-5, atol=2e-5)


def test_ops_dispatch_by_rhs_rank():
    """ops wrappers route (k,B) and (k,B,R) to the right backend kernels."""
    L, r = _tri(3, 16, np.float32, seed=2)
    rp = jnp.asarray(np.random.default_rng(2).uniform(-1, 1, (3, 16, 4)).astype(np.float32))
    for backend in ("reference", "pallas"):
        out1 = ops.batched_block_trsv(L, r, backend=backend)
        out2 = ops.batched_block_trsv(L, rp, backend=backend)
        assert out1.shape == (3, 16) and out2.shape == (3, 16, 4)
        np.testing.assert_allclose(out1, ref.block_trsv_ref(L, r), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(out2, ref.block_trsv_ref(L, rp), rtol=2e-5, atol=2e-5)
        g1 = ops.batched_block_gemv(L, r, backend=backend)
        g2 = ops.batched_block_gemv(L, rp, backend=backend)
        np.testing.assert_allclose(g1, ref.block_gemv_ref(L, r), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(g2, ref.block_gemv_ref(L, rp), rtol=2e-5, atol=2e-5)
