"""Krylov subsystem end-to-end: PCG/BiCGStab with distributed SpTRSV
preconditioner application, validated against scipy.sparse.linalg oracles."""
import numpy as np
import pytest
import scipy.sparse.linalg as spla

import strategies
from strategies import mesh1 as _mesh1
from repro.core import DistributedSolver, SolverConfig, build_plan
from repro.krylov import (
    DistributedSpMV,
    solve_cg,
    solve_ic0_pcg,
    solve_ilu0_bicgstab,
    spd_lower_from_triangular,
    symmetric_full_csr,
)
from repro.sparse import suite
from repro.sparse.matrix import reference_solve, to_scipy


@pytest.fixture(scope="module")
def spd_problem():
    """grid2d_factor-derived SPD system (the paper's structured-grid regime)."""
    return strategies.spd_problem(side=18, seed=0)


CFG = SolverConfig(block_size=16)


def test_distributed_spmv_matches_scipy(spd_problem):
    a, _, full = spd_problem
    spmv = DistributedSpMV(build_plan(a, 1, CFG), _mesh1())
    rng = np.random.default_rng(1)
    v = rng.uniform(-1, 1, a.n)
    np.testing.assert_allclose(spmv.matvec(v), full @ v, rtol=1e-4, atol=1e-4)
    V = rng.uniform(-1, 1, (a.n, 3))
    np.testing.assert_allclose(spmv.matvec(V), full @ V, rtol=1e-4, atol=1e-4)
    assert spmv.n_matvecs == 2


def test_ic0_pcg_beats_plain_cg(spd_problem):
    """Acceptance: rel. residual <= 1e-8 in strictly fewer iterations than
    unpreconditioned CG, with BOTH triangular sweeps going through
    DistributedSolver (invocation-counted)."""
    a, b, full = spd_problem
    res_cg = solve_cg(a, b, mesh=_mesh1(), config=CFG, tol=1e-8)
    res_pcg = solve_ic0_pcg(a, b, mesh=_mesh1(), config=CFG, tol=1e-8)
    assert res_cg.converged and res_pcg.converged
    assert float(np.max(res_pcg.relres)) <= 1e-8
    assert res_pcg.n_iters < res_cg.n_iters
    # both sweeps are compiled DistributedSolver instances, invoked per iteration
    fwd, bwd = res_pcg.info["forward"], res_pcg.info["backward"]
    assert isinstance(fwd, DistributedSolver) and isinstance(bwd, DistributedSolver)
    assert fwd.n_solves == bwd.n_solves == res_pcg.n_iters
    assert bwd.plan.transpose and not fwd.plan.transpose
    # and the answer is right
    x_ref = spla.spsolve(full, b)
    np.testing.assert_allclose(res_pcg.x, x_ref, rtol=1e-5, atol=1e-5)


def test_pcg_multirhs_matches_independent_scipy_solves(spd_problem):
    """Acceptance: k > 1 RHS panel through one compiled solve pair matches the
    k independent scipy solves to 1e-5."""
    a, _, full = spd_problem
    k = 4
    B = np.random.default_rng(2).uniform(-1, 1, (a.n, k))
    res = solve_ic0_pcg(a, B, mesh=_mesh1(), config=CFG, tol=1e-10, maxiter=300)
    assert res.converged
    # one compiled solve served all k systems per iteration
    assert res.info["forward"].n_solves == res.n_iters
    x_ref = np.column_stack([spla.spsolve(full, B[:, j]) for j in range(k)])
    np.testing.assert_allclose(res.x, x_ref, rtol=1e-5, atol=1e-5)


def test_multirhs_solve_blocks_matches_scipy(spd_problem):
    """Raw solver check of the same acceptance bound, straight on L."""
    a, _, _ = spd_problem
    k = 3
    B = np.random.default_rng(3).uniform(-1, 1, (a.n, k))
    solver = DistributedSolver(build_plan(a, 1, CFG), _mesh1())
    X = solver.solve(B)
    for j in range(k):
        np.testing.assert_allclose(X[:, j], reference_solve(a, B[:, j]),
                                   rtol=1e-5, atol=1e-5)


def test_ilu0_bicgstab_converges(spd_problem):
    a, b, full = spd_problem
    res = solve_ilu0_bicgstab(a, b, mesh=_mesh1(), config=CFG, tol=1e-8)
    assert res.converged
    # two preconditioner applications per BiCGStab iteration
    assert res.info["forward"].n_solves == 2 * res.n_iters
    assert res.info["backward"].n_solves == 2 * res.n_iters
    np.testing.assert_allclose(res.x, spla.spsolve(full, b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("comm,sched", [("zerocopy", "levelset"),
                                        ("unified", "levelset"),
                                        ("zerocopy", "syncfree")])
def test_pcg_all_solver_modes(comm, sched):
    a = spd_lower_from_triangular(suite.grid2d_factor(12, seed=5))
    b = np.random.default_rng(6).uniform(-1, 1, a.n)
    cfg = SolverConfig(block_size=8, comm=comm, sched=sched)
    res = solve_ic0_pcg(a, b, mesh=_mesh1(), config=cfg, tol=1e-8)
    assert res.converged
    full = to_scipy(symmetric_full_csr(a)).tocsc()
    np.testing.assert_allclose(res.x, spla.spsolve(full, b), rtol=1e-5, atol=1e-5)


def test_pcg_iteration_history_monotone_tail(spd_problem):
    """History is recorded and reaches the tolerance at the final entry."""
    a, b, _ = spd_problem
    res = solve_ic0_pcg(a, b, mesh=_mesh1(), config=CFG, tol=1e-8)
    assert len(res.history) == res.n_iters + 1
    assert res.history[-1] <= 1e-8
