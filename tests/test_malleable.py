"""Malleable cost-model partition: invariants, balance acceptance, agreement.

Unlike test_partition.py this module does not need hypothesis, so the
acceptance checks for the malleable strategy always run.
"""
import numpy as np
import pytest

from strategies import mesh1 as _mesh1, random_blocks as _blocks
from repro.core import SolverConfig, build_plan, sptrsv
from repro.core.blocking import build_blocks
from repro.core.partition import block_row_cost, cut_stats, make_partition
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


# ---------------------------------------------------------------------------
# partition invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("D,tpd", [(1, 8), (3, 4), (4, 8), (8, 2)])
def test_malleable_invariants(D, tpd):
    bs = _blocks(seed=5)
    part = make_partition(bs, D, "malleable", tpd)
    # every block row owned exactly once, by a real device
    assert part.owner.shape == (bs.nb,)
    assert part.owner.min() >= 0 and part.owner.max() < D
    # boundary mask matches tile ownership exactly
    remote = part.owner[bs.off_cols] != part.owner[bs.off_rows]
    expect = np.zeros(bs.nb, bool)
    expect[bs.off_rows[remote]] = True
    assert np.array_equal(part.boundary, expect)
    if D == 1:
        assert not part.boundary.any()


def test_malleable_single_device_owns_everything():
    bs = _blocks(seed=6)
    part = make_partition(bs, 1, "malleable", 8)
    assert np.array_equal(part.owner, np.zeros(bs.nb, np.int32))


def test_block_row_cost_counts_column_tiles():
    bs = _blocks(seed=8)
    cost = block_row_cost(bs)
    assert cost.shape == (bs.nb,)
    col_tiles = np.bincount(bs.off_cols, minlength=bs.nb)
    np.testing.assert_allclose(cost, 1.0 + 2.0 * col_tiles)


def test_unknown_strategy_raises():
    bs = _blocks()
    with pytest.raises(ValueError):
        make_partition(bs, 4, "nope")


# ---------------------------------------------------------------------------
# balance acceptance vs the round-robin task pool
# ---------------------------------------------------------------------------

SKEWED = ("chipcool0", "pkustk14", "shipsec1", "dblp-2010")


def test_malleable_beats_taskpool_level_balance_on_skewed_suites():
    """Acceptance: per-level LPT placement never loses to the round-robin deal
    on the paper's skewed (chain-dominated / banded) matrices, and wins
    strictly on at least one of them."""
    deltas = []
    for e in suite.table1_suite(0.05):
        if e.name not in SKEWED:
            continue
        bs = build_blocks(e.build(), 16)
        mal = cut_stats(bs, make_partition(bs, 4, "malleable", 8))
        tp = cut_stats(bs, make_partition(bs, 4, "taskpool", 8))
        assert mal.level_imbalance <= tp.level_imbalance + 1e-9, e.name
        deltas.append(tp.level_imbalance - mal.level_imbalance)
    assert len(deltas) == len(SKEWED)
    assert max(deltas) > 1e-6  # strictly lower somewhere


def test_cut_stats_cost_imbalance_present():
    bs = _blocks(seed=7)
    cs = cut_stats(bs, make_partition(bs, 4, "malleable", 8))
    assert cs.level_cost_imbalance >= 1.0
    assert cs.level_imbalance >= 1.0


# ---------------------------------------------------------------------------
# solution agreement across strategies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_strategies_agree_bit_exact_and_match_reference(comm, sched):
    """All three partition strategies produce the same solution (bit-exact on
    one device) and match the scipy oracle, in all four sched x comm modes."""
    a = suite.random_levelled(400, 24, 4.0, seed=3)
    b = np.random.default_rng(0).uniform(-1, 1, a.n)
    x_ref = reference_solve(a, b)
    mesh = _mesh1()
    xs = {}
    for part in ("taskpool", "contiguous", "malleable"):
        cfg = SolverConfig(block_size=16, comm=comm, sched=sched, partition=part)
        xs[part] = sptrsv(a, b, mesh=mesh, config=cfg)
        np.testing.assert_allclose(xs[part], x_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(xs["taskpool"], xs["contiguous"])
    np.testing.assert_array_equal(xs["taskpool"], xs["malleable"])


def test_malleable_partition_reuse_in_plan():
    """A malleable partition built for one pattern is reusable by build_plan
    (the zero-fill-factor sharing path the Krylov front doors rely on)."""
    a = suite.grid2d_factor(16, seed=2)
    cfg = SolverConfig(block_size=16, partition="malleable")
    plan_a = build_plan(a, 1, cfg)
    plan_b = build_plan(a, 1, cfg, part=plan_a.part)
    assert plan_b.part is plan_a.part
    b = np.random.default_rng(1).uniform(-1, 1, a.n)
    from repro.core import DistributedSolver

    x = DistributedSolver(plan_b, _mesh1()).solve(b)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)
