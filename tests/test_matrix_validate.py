"""CSC.validate: degenerate sizes and full structural checks (bugfix regression).

Separate from test_sparse.py so it runs even without the optional hypothesis
dependency.
"""
import numpy as np
import pytest

from repro.sparse.matrix import CSC, CSR, csr_to_csc, lower_triangular_from_coo


def _csc(n=40, seed=0, m=160):
    rng = np.random.default_rng(seed)
    a = lower_triangular_from_coo(n, rng.integers(0, n, m), rng.integers(0, n, m), rng=rng)
    return csr_to_csc(a)


def test_validate_accepts_well_formed():
    _csc().validate()


def test_validate_empty_matrix():
    """n == 0 used to crash on the row_idx[col_ptr[-1]] spot-check."""
    CSC(n=0, col_ptr=np.zeros(1, np.int64), row_idx=np.zeros(0, np.int32),
        val=np.zeros(0)).validate()


def test_validate_single_entry():
    CSC(n=1, col_ptr=np.array([0, 1], np.int64), row_idx=np.array([0], np.int32),
        val=np.ones(1)).validate()


def test_validate_rejects_missing_diagonal_start():
    c = _csc(seed=1)
    bad = c.row_idx.copy()
    j = int(np.argmax(np.diff(c.col_ptr) > 1))  # a column with >1 entry
    bad[c.col_ptr[j]] = min(c.n - 1, int(bad[c.col_ptr[j]]) + 1)
    with pytest.raises(AssertionError):
        CSC(n=c.n, col_ptr=c.col_ptr, row_idx=bad, val=c.val).validate()


def test_validate_rejects_unsorted_rows_in_column():
    c = _csc(seed=2)
    lens = np.diff(c.col_ptr)
    j = int(np.argmax(lens >= 3))  # column with >= 3 entries: swap its tail
    assert lens[j] >= 3
    bad = c.row_idx.copy()
    s = int(c.col_ptr[j])
    bad[s + 1], bad[s + 2] = bad[s + 2], bad[s + 1]
    with pytest.raises(AssertionError):
        CSC(n=c.n, col_ptr=c.col_ptr, row_idx=bad, val=c.val).validate()


def test_validate_rejects_length_mismatch():
    c = _csc(seed=3)
    with pytest.raises(AssertionError):
        CSC(n=c.n, col_ptr=c.col_ptr, row_idx=c.row_idx[:-1], val=c.val).validate()
