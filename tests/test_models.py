"""Per-arch smoke tests (reduced configs): forward + one train step on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config, get_reduced
from repro.data import SyntheticLM
from repro.models import forward, init_cache, init_params, param_count
from repro.models.model import encode, loss_fn
from repro.train.optim import adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=32):
    if cfg.input_kind == "tokens":
        return jax.random.randint(KEY, (B, S), 0, cfg.vocab), None
    return None, jax.random.normal(KEY, (B, S, cfg.d_model))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, KEY)
    tokens, embeds = _inputs(cfg)
    enc_out = None
    if cfg.enc_layers:
        enc_out = encode(params, cfg, jax.random.normal(KEY, (2, cfg.enc_seq, cfg.d_model)))
    logits, _ = forward(params, cfg, tokens, embeds=embeds, enc_out=enc_out)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss_shape(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, KEY)
    opt = adamw_init(params)
    data = SyntheticLM(cfg, global_batch=2, seq_len=32)
    batch = data.batch(0)

    def lf(p, b):
        return loss_fn(p, cfg, b.get("tokens"), b.get("labels"),
                       embeds=b.get("embeds"), enc_embeds=b.get("enc_embeds"),
                       remat=False)

    loss, grads = jax.value_and_grad(lf)(params, batch)
    assert np.isfinite(float(loss))
    new_params, opt, gnorm = adamw_update(params, grads, opt, lr=1e-3)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ["gemma2-2b", "falcon-mamba-7b", "llama4-maverick-400b-a17b",
                                  "seamless-m4t-medium"])
def test_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32", param_dtype="float32")
    params = init_params(cfg, KEY)
    B, S = 2, 16
    tokens, embeds = _inputs(cfg, B, S)
    enc_out = None
    if cfg.enc_layers:
        enc_out = encode(params, cfg, jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model)))
    full, _ = forward(params, cfg, tokens, embeds=embeds, enc_out=enc_out)
    cache = init_cache(cfg, B, max_seq=S)
    outs = []
    for t in range(S):
        tk = tokens[:, t:t + 1] if tokens is not None else None
        em = embeds[:, t:t + 1] if embeds is not None else None
        lg, cache = forward(params, cfg, tk, embeds=em, cache=cache,
                            pos_offset=t, enc_out=enc_out if t == 0 else None)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))
    assert err < 5e-4, err


def test_full_config_param_counts_match_names():
    """The full configs must hit their advertised parameter counts (±25%)."""
    expected = {
        "zamba2-7b": 7e9, "llama4-maverick-400b-a17b": 400e9, "arctic-480b": 480e9,
        "falcon-mamba-7b": 7e9, "granite-34b": 34e9, "gemma2-2b": 2.6e9,
        "llama3.2-1b": 1.2e9, "yi-6b": 6e9, "internvl2-1b": 0.6e9,
    }
    for arch, target in expected.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
        n = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
        assert 0.6 * target < n < 1.45 * target, (arch, f"{n:.3e}", target)


def test_cell_applicability_rules():
    runs = {(a, s) for a in ARCH_IDS for s in SHAPES if cell_applicable(a, s)[0]}
    assert ("falcon-mamba-7b", "long_500k") in runs
    assert ("zamba2-7b", "long_500k") in runs
    assert ("granite-34b", "long_500k") not in runs
    assert ("gemma2-2b", "long_500k") not in runs  # global layers are quadratic
    assert len([c for c in runs if c[1] != "long_500k"]) == 30
