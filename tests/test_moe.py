"""MoE routing invariants (hypothesis over router inputs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models.moe import init_moe, moe_ffn

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    return dataclasses.replace(
        get_reduced("arctic-480b"), dtype="float32", param_dtype="float32", **kw
    )


@given(st.integers(0, 2**31 - 1), st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_moe_output_finite_and_shaped(seed, k):
    cfg = _cfg(top_k=k)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg.d_model))
    y = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_matches_dense_expert_evaluation():
    """No-drop small-N routing must equal explicitly computed top-k experts."""
    cfg = _cfg(top_k=2, moe_dense_ff=0)
    p = init_moe(KEY, cfg, jnp.float32)
    # drop the dense residual for the exactness check
    p.pop("dense", None)
    B, S, d = 2, 8, cfg.d_model
    x = jax.random.normal(KEY, (B, S, d))
    y = moe_ffn(p, x, cfg)

    xt = x.reshape(-1, d)
    probs = jax.nn.softmax(xt @ p["router"], -1)
    gate, choice = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for i in range(xt.shape[0]):
        acc = jnp.zeros((d,))
        for j in range(cfg.top_k):
            e = int(choice[i, j])
            h = xt[i] @ p["w1"][e]
            gz = xt[i] @ p["w3"][e]
            acc += gate[i, j] * ((jax.nn.silu(h) * gz) @ p["w2"][e])
        ref = ref.at[i].set(acc)
    np.testing.assert_allclose(y.reshape(-1, d), ref, rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity routing, at most C pairs are processed per expert."""
    cfg = _cfg(top_k=1, capacity_factor=1.0)
    p = init_moe(KEY, cfg, jnp.float32)
    N = 8192  # force the capacity path (> 4096 pairs)
    x = jax.random.normal(KEY, (1, N, cfg.d_model))
    y = moe_ffn(p, x, cfg)  # must not error; drops silently bounded
    assert y.shape == (1, N, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(y)))
