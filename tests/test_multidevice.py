"""Multi-device behaviour (subprocess with 8 forced host CPU devices).

The main test process keeps 1 device (dry-run contract); anything needing a
real multi-device mesh runs here via subprocess.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=1200, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_solver_all_modes_on_8_devices():
    print(run_py("""
        import numpy as np, jax
        from repro.sparse import suite
        from repro.sparse.matrix import reference_solve
        from repro.core import sptrsv, SolverConfig
        a = suite.random_levelled(600, 24, 4.0, seed=5)
        b = np.random.default_rng(1).uniform(-1, 1, a.n)
        x_ref = reference_solve(a, b)
        from repro import compat
        mesh = compat.make_mesh((8,), ("x",))
        for comm in ["zerocopy", "unified"]:
            for sched in ["levelset", "dagpart", "syncfree"]:
                for part in ["taskpool", "contiguous", "malleable"]:
                    cfg = SolverConfig(block_size=16, comm=comm, sched=sched, partition=part)
                    x = sptrsv(a, b, mesh=mesh, config=cfg)
                    err = np.abs(x - x_ref).max() / np.abs(x_ref).max()
                    assert err < 1e-5, (comm, sched, part, err)
        print("OK")
    """))


@pytest.mark.slow
def test_fused_backend_bit_exact_all_modes_on_8_devices():
    """Fused superstep megakernel / frontier-bucketed syncfree vs the
    lax.switch / dense executors, all sched x comm modes (including dagpart
    merged supersteps), on a real 8-device mesh. Exact-arithmetic (dyadic)
    values make the bitwise comparison meaningful — see
    tests/test_superstep.py."""
    print(run_py("""
        import numpy as np, jax
        from repro import compat
        from repro.core import DistributedSolver, SolverConfig, build_plan
        from repro.sparse import suite
        from repro.sparse.matrix import CSR, reference_solve

        a0 = suite.random_levelled(400, 8, 4.0, seed=6)
        rows = np.repeat(np.arange(a0.n), np.diff(a0.row_ptr))
        rng = np.random.default_rng(0)
        signs = rng.choice(np.array([-0.5, -0.25, 0.25, 0.5], np.float32),
                           size=a0.val.shape)
        val = np.where(a0.col_idx == rows, 1.0, signs).astype(np.float32)
        a = CSR(n=a0.n, row_ptr=a0.row_ptr, col_idx=a0.col_idx, val=val)
        b = np.random.default_rng(1).integers(-4, 5, a.n).astype(np.float32)
        x_ref = reference_solve(a, b)
        mesh = compat.make_mesh((8,), ("x",))
        for comm in ("zerocopy", "unified"):
            for sched in ("levelset", "dagpart", "syncfree"):
                ref_backend = "pallas" if sched != "syncfree" else None
                sw = DistributedSolver(build_plan(a, 8, SolverConfig(
                    block_size=16, comm=comm, sched=sched,
                    kernel_backend=ref_backend)), mesh)
                fu = DistributedSolver(build_plan(a, 8, SolverConfig(
                    block_size=16, comm=comm, sched=sched,
                    kernel_backend="fused")), mesh)
                xs, xf = sw.solve(b), fu.solve(b)
                assert np.array_equal(xs, xf), (comm, sched)
                assert np.array_equal(xf, x_ref.astype(np.float32)), (comm, sched)
        print("OK")
    """))


@pytest.mark.slow
def test_streamed_store_bit_exact_all_modes_on_8_devices():
    """Streaming HBM tile store vs the resident fused megakernel, all
    sched x comm modes (including dagpart merged supersteps), on a real
    8-device mesh — bit-identical on the dyadic exact-arithmetic structure
    (for sched="syncfree" the streamed backend is defined to behave exactly
    like "fused"; asserting equality there pins that contract too)."""
    print(run_py("""
        import numpy as np, jax
        from repro import compat
        from repro.core import DistributedSolver, SolverConfig, build_plan
        from repro.core.solver import dispatch_stats, fused_streaming
        from repro.sparse import suite
        from repro.sparse.matrix import CSR, reference_solve

        a0 = suite.random_levelled(400, 8, 4.0, seed=6)
        rows = np.repeat(np.arange(a0.n), np.diff(a0.row_ptr))
        rng = np.random.default_rng(0)
        signs = rng.choice(np.array([-0.5, -0.25, 0.25, 0.5], np.float32),
                           size=a0.val.shape)
        val = np.where(a0.col_idx == rows, 1.0, signs).astype(np.float32)
        a = CSR(n=a0.n, row_ptr=a0.row_ptr, col_idx=a0.col_idx, val=val)
        b = np.random.default_rng(1).integers(-4, 5, a.n).astype(np.float32)
        x_ref = reference_solve(a, b)
        mesh = compat.make_mesh((8,), ("x",))
        for comm in ("zerocopy", "unified"):
            for sched in ("levelset", "dagpart", "syncfree"):
                fu = DistributedSolver(build_plan(a, 8, SolverConfig(
                    block_size=16, comm=comm, sched=sched,
                    kernel_backend="fused")), mesh)
                st_plan = build_plan(a, 8, SolverConfig(
                    block_size=16, comm=comm, sched=sched,
                    kernel_backend="fused_streamed"))
                st = DistributedSolver(st_plan, mesh)
                if sched in ("levelset", "dagpart"):
                    ds = dispatch_stats(st_plan)
                    assert fused_streaming(st_plan) and ds["streamed"], (comm, sched)
                    assert ds["stream_dma_bytes"] > 0, (comm, sched)
                if sched == "dagpart":
                    assert ds["supersteps"] <= ds["supersteps_levelset"], comm
                xf, xs = fu.solve(b), st.solve(b)
                assert np.array_equal(xf, xs), (comm, sched)
                assert np.array_equal(xs, x_ref.astype(np.float32)), (comm, sched)
        print("OK")
    """))


@pytest.mark.slow
def test_numeric_refresh_bit_identical_all_modes_on_8_devices():
    """Factorizing new values through the session context must be
    bit-identical to a fresh build_plan on the same pattern — plans AND
    executed solves, across all sched x comm modes, on 8 devices."""
    print(run_py("""
        import numpy as np, jax
        from repro import compat
        from repro.api import SpTRSVContext, PlanOptions
        from repro.core import DistributedSolver, SolverConfig, build_plan
        from repro.sparse import suite
        from repro.sparse.matrix import CSR

        a = suite.random_levelled(600, 24, 4.0, seed=5)
        a2 = CSR(n=a.n, row_ptr=a.row_ptr, col_idx=a.col_idx,
                 val=a.val * (1.0 + 0.25 * np.sin(np.arange(a.nnz))))
        b = np.random.default_rng(1).uniform(-1, 1, a.n)
        mesh = compat.make_mesh((8,), ("x",))
        for comm in ("zerocopy", "unified"):
            for sched in ("levelset", "dagpart", "syncfree"):
                cfg = SolverConfig(block_size=16, comm=comm, sched=sched)
                ctx = SpTRSVContext(mesh=mesh, options=cfg)
                h = ctx.analyse(a)
                ctx.solve(h, b)  # compile on a's values
                ctx.factorize(a2, h)
                fresh = build_plan(a2, 8, cfg)
                refreshed = ctx.plan(h)
                assert np.array_equal(refreshed.diag, fresh.diag), (comm, sched)
                assert np.array_equal(refreshed.tiles, fresh.tiles), (comm, sched)
                x_ctx = ctx.solve(h, b)
                x_fresh = DistributedSolver(fresh, mesh).solve(b)
                assert np.array_equal(x_ctx, x_fresh), (comm, sched)
                assert ctx.stats()["analyses"] == 1, (comm, sched)
        print("OK")
    """))


@pytest.mark.slow
def test_lm_train_step_on_4_device_mesh():
    print(run_py("""
        import jax, numpy as np
        from repro.configs import get_reduced
        from repro.data import SyntheticLM
        from repro.models import init_params
        from repro.train.optim import adamw_init
        from repro.train.step import make_train_step
        from repro import compat
        mesh = compat.make_mesh((2, 2), ("data", "model"))
        with compat.set_mesh(mesh):
            cfg = get_reduced("llama3.2-1b")
            params = init_params(cfg, jax.random.PRNGKey(0))
            opt = adamw_init(params)
            data = SyntheticLM(cfg, 4, 32)
            step = make_train_step(cfg, mesh, example_params=params,
                                   example_opt=opt, example_batch=data.batch(0))
            losses = []
            for s in range(3):
                params, opt, m = step(params, opt, data.batch(s), np.int32(s))
                losses.append(float(m["loss"]))
            assert all(np.isfinite(l) for l in losses), losses
        print("OK")
    """, devices=4))


@pytest.mark.slow
def test_serve_decode_on_4_device_mesh():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import init_cache, init_params
        from repro.serve.engine import make_decode_step, make_prefill_step
        from repro import compat
        mesh = compat.make_mesh((2, 2), ("data", "model"))
        with compat.set_mesh(mesh):
            cfg = get_reduced("llama3.2-1b")
            params = init_params(cfg, jax.random.PRNGKey(0))
            B, S = 4, 32
            cache = init_cache(cfg, B, S + 8)  # prefill-into-larger-cache path
            batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
            prefill = make_prefill_step(cfg, mesh, example_params=params,
                                        example_cache=cache, example_batch=batch)
            logits, cache = prefill(params, batch, cache)
            dec_batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
            decode = make_decode_step(cfg, mesh, example_params=params,
                                      example_cache=cache, example_batch=dec_batch)
            for t in range(3):
                tok, cache = decode(params, dec_batch, cache, jnp.int32(S + t))
            assert tok.shape == (B,)
        print("OK")
    """, devices=4))


@pytest.mark.slow
def test_krylov_pcg_on_4_devices():
    """IC(0)-PCG with distributed SpMV + L/L^T solves on a real 4-device mesh."""
    print(run_py("""
        import numpy as np
        import scipy.sparse.linalg as spla
        from repro import compat
        from repro.core import SolverConfig
        from repro.krylov import solve_ic0_pcg, spd_lower_from_triangular, symmetric_full_csr
        from repro.sparse import suite
        from repro.sparse.matrix import to_scipy
        a = spd_lower_from_triangular(suite.grid2d_factor(16, seed=1))
        b = np.random.default_rng(2).uniform(-1, 1, a.n)
        mesh = compat.make_mesh((4,), ("x",))
        res = solve_ic0_pcg(a, b, mesh=mesh,
                            config=SolverConfig(block_size=8, comm="zerocopy"), tol=1e-8)
        assert res.converged, res.n_iters
        assert res.info["forward"].n_solves == res.n_iters
        x_ref = spla.spsolve(to_scipy(symmetric_full_csr(a)).tocsc(), b)
        err = np.abs(res.x - x_ref).max() / np.abs(x_ref).max()
        assert err < 1e-5, err
        print("OK")
    """, devices=4)
    )
