"""Unified telemetry layer: spans, metrics registry, calibration feedback.

Covers the ISSUE-6 acceptance points: deterministic span nesting/ordering,
metrics snapshots reconciling field-for-field with ``dispatch_stats`` /
``cut_stats``, bit-identical solves with tracing on vs off across all kernel
backends (no retrace when toggling), and the calibration round-trip — probed
samples persisted, reloaded, and fitted weights applied by a probe-free
``calibrate_weights`` call.
"""
import json

import numpy as np
import pytest

import strategies as st
from repro.api import PlanOptions, SpTRSVContext
from repro.api.autotune import plan_work_units, tune
from repro.core.costmodel import calibrate_weights, hlo_weights
from repro.core.partition import cut_stats
from repro.core.solver import DistributedSolver, build_plan, dispatch_stats
from repro.kernels import ops
from repro.obs import calibration as cal
from repro.obs import metrics as met
from repro.obs import trace as tr
from repro.sparse import suite


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Each test gets a pristine global tracer/registry/calibration store."""
    tr.configure_tracing(enabled=False)
    met.get_registry().clear()
    cal.set_store(cal.CalibrationStore())
    yield
    tr.configure_tracing(enabled=False)
    met.get_registry().clear()
    cal.set_store(None)


def small_problem(n=120, levels=6, seed=3):
    a = st.dyadic(suite.random_levelled(n, levels, 4.0, seed=seed))
    b = st.dyadic_rhs(a.n, seed=seed + 1)
    return a, b


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------


def test_span_nesting_and_ordering_deterministic(tmp_path):
    path = str(tmp_path / "t.jsonl")
    a, b = small_problem()
    with tr.trace_to(path) as tracer:
        ctx = SpTRSVContext(mesh=st.mesh1())
        h = ctx.analyse(a)
        ctx.solve(h, b)
        recs = tracer.export()
    spans = {r["id"]: r for r in recs if r["type"] == "span"}
    by_name = {}
    for r in spans.values():
        by_name.setdefault(r["name"], []).append(r)
    for name in ("sptrsv.analyse", "sptrsv.partition", "sptrsv.schedule",
                 "sptrsv.solve"):
        assert name in by_name, name
    # ids are the open order: analyse opens before its children. The
    # partition is built inside analyse; the schedule is built lazily at the
    # first solve (plan construction is deferred outside auto mode), so it is
    # a top-level span here.
    analyse = by_name["sptrsv.analyse"][0]
    child = by_name["sptrsv.partition"][0]
    assert child["parent"] == analyse["id"]
    assert child["id"] > analyse["id"]
    assert by_name["sptrsv.schedule"][0]["parent"] is None
    assert by_name["sptrsv.solve"][0]["parent"] is None
    # JSONL sink carries the same records, one valid object per line, in
    # close order (children before parents); ids reconstruct the open order
    lines = [json.loads(line) for line in open(path)]
    line_ids = [r["id"] for r in lines if r["type"] == "span"]
    assert line_ids == [r["id"] for r in recs if r["type"] == "span"]
    assert sorted(line_ids) == list(range(len(line_ids)))


def test_factorize_and_refresh_spans():
    a, b = small_problem()
    a2 = st.dyadic(a, seed=9)  # same pattern, new values
    with tr.trace_to() as tracer:
        ctx = SpTRSVContext(mesh=st.mesh1())
        h = ctx.analyse(a)
        ctx.solve(h, b)
        ctx.factorize(a2, h)
        names = {r["name"] for r in tracer.export()}
    assert "sptrsv.factorize" in names
    assert "sptrsv.refresh" in names  # refresh_plan ran under the factorize


def test_disabled_tracer_is_shared_noop():
    tracer = tr.get_tracer()
    assert tracer is tr.NULL_TRACER and not tracer.enabled
    s1, s2 = tracer.span("a", x=1), tracer.span("b")
    assert s1 is s2  # the shared null span: no allocation per call
    with s1 as s:
        assert s.set(anything=True) is s
    assert tracer.export() == []


def test_trace_to_restores_previous_tracer():
    before = tr.get_tracer()
    with tr.trace_to() as tracer:
        assert tr.get_tracer() is tracer
    assert tr.get_tracer() is before


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_instrument_types_and_snapshot(tmp_path):
    reg = met.MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.gauge("g").set(2.5)
    for v in (10.0, 30.0):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["c"] == 3
    assert snap["g"] == 2.5
    assert snap["h"] == {"count": 2, "sum": 40.0, "min": 10.0, "max": 30.0,
                         "mean": 20.0, "last": 30.0}
    with pytest.raises(TypeError):
        reg.gauge("c")
    path = str(tmp_path / "m.jsonl")
    written = reg.dump(path)
    rec = json.loads(open(path).read())
    assert rec["type"] == "metrics" and rec["metrics"] == written == snap


def test_plan_metrics_match_dispatch_and_cut_stats():
    a, _ = small_problem()
    plan = build_plan(a, 2)  # host-built D=2 plan: no devices needed
    reg = met.MetricsRegistry()
    met.record_plan_metrics(reg, plan)
    snap = reg.snapshot()
    ds = dispatch_stats(plan)
    for k, v in ds.items():
        assert snap[f"plan.{k}"] == (int(v) if isinstance(v, bool) else v), k
    cs = cut_stats(plan.bs, plan.part)
    assert snap["plan.boundary_rows"] == cs.boundary_rows
    assert snap["plan.boundary_fraction"] == pytest.approx(cs.boundary_fraction)
    assert snap["plan.level_cost_imbalance"] == pytest.approx(
        cs.level_cost_imbalance)
    assert snap["plan.comm_bytes_per_solve"] == plan.comm_bytes_per_solve
    assert snap["plan.n_boundary_rows"] == plan.n_boundary_rows


def test_context_metrics_snapshot_counters_and_histogram():
    a, b = small_problem()
    ctx = SpTRSVContext(mesh=st.mesh1(), registry=met.MetricsRegistry())
    h = ctx.analyse(a)
    for _ in range(3):
        ctx.solve(h, b)
    snap = ctx.metrics_snapshot(h)
    assert snap["session.analyses"] == 1
    assert snap["session.solves"] == 3
    assert snap["session.solve_cache_misses"] == 1
    assert snap["session.solve_cache_hits"] == 2
    assert snap["session.solve_us"]["count"] == 3
    assert snap["session.solve_us"]["min"] > 0
    assert snap["session.cache_hit_rate"] == ctx.stats()["cache_hit_rate"]
    assert snap["plan.n_levels"] == ctx.plan(h).n_levels


# ---------------------------------------------------------------------------
# tracing on/off: bit-identity and no retrace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ops.BACKENDS)
def test_solves_bit_identical_tracing_on_vs_off(backend):
    a, b = small_problem()
    assert st.exactness_holds(a, b)
    opts = PlanOptions(kernel=backend, block_size=16)
    tr.configure_tracing(enabled=False)
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts)
    x_off = ctx.solve(ctx.analyse(a), b)
    with tr.trace_to() as tracer:
        ctx2 = SpTRSVContext(mesh=st.mesh1(), options=opts)
        x_on = ctx2.solve(ctx2.analyse(a), b)
        assert {r["name"] for r in tracer.export()} >= {"sptrsv.solve"}
    np.testing.assert_array_equal(np.asarray(x_off), np.asarray(x_on))


def test_toggling_tracing_does_not_retrace():
    a, b = small_problem()
    ctx = SpTRSVContext(mesh=st.mesh1())
    h = ctx.analyse(a)
    ctx.solve(h, b)
    jitted = ctx.executor(h)._jitted
    if not hasattr(jitted, "_cache_size"):
        pytest.skip("jit cache size introspection unavailable")
    size = jitted._cache_size()
    with tr.trace_to():
        ctx.solve(h, b)
    ctx.solve(h, b)
    assert jitted._cache_size() == size  # same trace served all three


# ---------------------------------------------------------------------------
# calibration feedback loop
# ---------------------------------------------------------------------------


def synthetic_samples(w_solve_us=3.0, c_tile=6.0, n=4):
    """Samples generated exactly by us = w_solve*su + c_tile*tu at R=1."""
    rng = np.random.default_rng(0)
    out = []
    for i in range(n):
        su = float(rng.integers(50, 400))
        tu = float(rng.integers(20, 300))
        out.append(dict(signature=f"sig{i}", su=su, tu=tu, tf=tu, R=1,
                        us=w_solve_us * su + c_tile * tu))
    return out


def record_all(store, samples, backend="reference", B=16):
    for s in samples:
        store.record(backend=backend, B=B, signature=s["signature"],
                     solve_units=s["su"], tile_units=s["tu"],
                     tile_flop_units=s["tf"], R=s["R"], measured_us=s["us"])


def test_calibration_fit_recovers_generating_weights():
    store = cal.CalibrationStore()
    record_all(store, synthetic_samples())
    w = store.fitted_weights(16, "reference")
    assert w is not None and w[0] == 1.0
    # uniform R=1 collapses tu/tf into one column: the fitted total tile
    # cost (mem + flop at R=1) must match the generator's ratio c_tile/w_solve
    assert w[1] + w[2] == pytest.approx(6.0 / 3.0, rel=1e-6)
    assert store.fitted_weights(16, "reference") is w  # cached identity


def test_calibration_underdetermined_returns_none():
    store = cal.CalibrationStore()
    assert store.fitted_weights(16, "reference") is None  # no samples
    record_all(store, synthetic_samples(n=1))
    assert store.fitted_weights(16, "reference") is None  # one sample
    # duplicate signature replaces, never stacks
    store2 = cal.CalibrationStore()
    record_all(store2, synthetic_samples(n=3))
    record_all(store2, synthetic_samples(n=3))
    assert store2.n_samples() == 3


def test_calibration_persist_reload_roundtrip(tmp_path):
    path = str(tmp_path / "weights.json")
    store = cal.CalibrationStore(path=path)
    record_all(store, synthetic_samples())  # record() persists each sample
    fresh = cal.CalibrationStore(path=path)  # a later session loads on init
    assert fresh.n_samples() == store.n_samples() == 4
    assert fresh.fitted_weights(16, "reference") == pytest.approx(
        store.fitted_weights(16, "reference"))


def test_probe_free_session_inherits_persisted_weights(tmp_path):
    path = str(tmp_path / "weights.json")
    record_all(cal.CalibrationStore(path=path), synthetic_samples())
    # "new session": a fresh global store pointed at the persisted file,
    # probe_solves=0 — calibrate_weights must prefer the fitted weights
    cal.set_store(cal.CalibrationStore(path=path))
    w = calibrate_weights(16, backend="reference")
    assert w == cal.get_store().fitted_weights(16, "reference")
    assert w[1] + w[2] == pytest.approx(2.0, rel=1e-6)
    assert calibrate_weights(16, backend="reference") is w  # stable identity
    # feedback off, or an empty store, falls back to the HLO estimate
    assert calibrate_weights(16, backend="reference", feedback=False) is \
        hlo_weights(16, "reference")
    cal.set_store(cal.CalibrationStore())
    assert calibrate_weights(16, backend="reference") is \
        hlo_weights(16, "reference")


def record_pair(store, ratio, B=16, n=3):
    """Paired fused / fused_streamed samples where the streamed executor
    costs ``ratio``x the resident one per schedule work unit."""
    for i in range(n):
        su, tu = 100.0 + 10 * i, 50.0 + 5 * i
        units = su + tu
        store.record(backend="fused", B=B, signature=f"f{i}",
                     solve_units=su, tile_units=tu, tile_flop_units=tu,
                     R=1, measured_us=2.0 * units)
        store.record(backend="fused_streamed", B=B, signature=f"s{i}",
                     solve_units=su, tile_units=tu, tile_flop_units=tu,
                     R=1, measured_us=2.0 * ratio * units)


def test_calibrated_stream_limit_scales_default_by_measured_ratio():
    from repro.core.solver import DEFAULT_STREAM_VMEM_LIMIT

    store = cal.CalibrationStore()
    assert cal.calibrated_stream_limit(store) is None  # no samples at all
    record_pair(store, ratio=2.0)  # streaming costs 2x per work unit
    assert cal.calibrated_stream_limit(store) == 2 * DEFAULT_STREAM_VMEM_LIMIT
    # near-free streaming drags the crossover down to the floor clamp,
    # pathological DMA cost saturates at the ceiling
    cheap, costly = cal.CalibrationStore(), cal.CalibrationStore()
    record_pair(cheap, ratio=0.01)
    record_pair(costly, ratio=1000.0)
    assert cal.calibrated_stream_limit(cheap) == cal.STREAM_LIMIT_FLOOR
    assert cal.calibrated_stream_limit(costly) == cal.STREAM_LIMIT_CEIL


def test_calibrated_stream_limit_needs_paired_backends():
    """Fused-only samples measure no crossover: callers must keep the fixed
    default rather than extrapolate from one executor."""
    store = cal.CalibrationStore()
    record_all(store, synthetic_samples(), backend="fused")
    assert cal.calibrated_stream_limit(store) is None


def test_stream_vmem_limit_resolution_order(monkeypatch):
    """env override > calibrated crossover > fixed default."""
    from repro.core.solver import DEFAULT_STREAM_VMEM_LIMIT, stream_vmem_limit

    monkeypatch.delenv("REPRO_STREAM_VMEM_LIMIT", raising=False)
    assert stream_vmem_limit() == DEFAULT_STREAM_VMEM_LIMIT  # pristine store
    record_pair(cal.get_store(), ratio=2.0)
    assert stream_vmem_limit() == 2 * DEFAULT_STREAM_VMEM_LIMIT
    monkeypatch.setenv("REPRO_STREAM_VMEM_LIMIT", "123456")
    assert stream_vmem_limit() == 123456  # env beats the measurement


def test_tune_probes_record_samples_and_compile_us(tmp_path):
    path = str(tmp_path / "weights.json")
    cal.set_store(cal.CalibrationStore(path=path))
    a, _ = small_problem(n=80, levels=5)
    opts = PlanOptions(sched="auto", comm="zerocopy", kernel="reference",
                       block_size=16, probe_solves=1)
    cfg, plan, decision, solver = tune(a, opts, st.mesh1())
    assert decision.mode == "probed"
    assert set(decision.compile_us) == set(decision.probe_us)
    assert all(us > 0 for us in decision.compile_us.values())
    # one sample per probed candidate (levelset/dagpart/syncfree), persisted
    # for the next session
    assert cal.get_store().n_samples() == len(decision.probe_us) == 3
    reloaded = cal.CalibrationStore(path=path)
    assert reloaded.n_samples() == 3
    # recorded work units are exactly what the scorer multiplies weights by
    combo = decision.chosen
    sig = cal.probe_signature(plan, opts.rhs_hint)
    sample = reloaded.samples(ops.executor_backend(combo[2]), 16)[sig]
    su, tu, tf = plan_work_units(plan, opts.rhs_hint)
    assert (sample["su"], sample["tu"], sample["tf"]) == (su, tu, tf)


# ---------------------------------------------------------------------------
# service telemetry (ISSUE 9): registry mirrors engine counters exactly
# ---------------------------------------------------------------------------


def serve_mix(registry=None, tracing=False, tmp_path=None):
    """One deterministic hot/cold mix through an engine; returns (engine,
    ordered results)."""
    from repro.service import SolveEngine

    mats = [st.dyadic(suite.random_levelled(n, 5, 3.0, seed=s))
            for n, s in ((96, 1), (64, 2))]
    kw = dict(mesh=st.mesh1(), options=PlanOptions(block_size=16),
              max_batch=4)
    if registry is not None:
        kw["registry"] = registry
    if tmp_path is not None:
        kw["plan_store"] = str(tmp_path / "plans")
    eng = SolveEngine(**kw)
    tickets = []
    for i in range(8):
        m = mats[0] if i % 3 else mats[1]
        tickets.append(eng.submit(f"t{i % 2}", m,
                                  st.dyadic_rhs(m.n, seed=i)))
    eng.drain()
    return eng, [np.asarray(t.result(0)) for t in tickets]


def test_service_metrics_reconcile_with_engine_counters(tmp_path):
    reg = met.MetricsRegistry()
    eng, _ = serve_mix(registry=reg, tmp_path=tmp_path)
    snap = reg.snapshot()
    stats = eng.stats()
    # every engine counter is mirrored under service.* with the same value
    # (same discipline as record_plan_metrics vs dispatch_stats)
    counters = {k: v for k, v in stats.items()
                if k not in ("queue_depth", "plan_store", "session")}
    assert counters, "engine produced no counters"
    for k, v in counters.items():
        assert snap[f"service.{k}"] == v, k
    assert snap["service.queue_depth"] == stats["queue_depth"] == 0
    # distribution instruments agree with the counted totals
    assert snap["service.coalesce_width"]["count"] == stats["batches"]
    assert snap["service.coalesce_width"]["sum"] == stats["coalesced_columns"]
    assert snap["service.request_us"]["count"] == stats["results"]
    assert snap["service.batch_us"]["count"] == stats["batches"]
    # the plan store mirrors its own counters and the derived hit-rate gauge
    ps = stats["plan_store"]
    for k, v in ps.items():
        if k != "hit_rate":
            assert snap[f"planstore.{k}"] == v, k
    assert snap["service.plan_store_hit_rate"] == pytest.approx(ps["hit_rate"])
    # and the session counters underneath are the ordinary session.* mirror
    for k, v in stats["session"].items():
        if k != "cache_hit_rate":
            assert snap[f"session.{k}"] == v, k


def test_served_results_bit_identical_tracing_on_vs_off():
    a_probe, _ = small_problem()
    assert st.exactness_holds(a_probe, st.dyadic_rhs(a_probe.n))
    tr.configure_tracing(enabled=False)
    _, off = serve_mix(registry=met.MetricsRegistry())
    with tr.trace_to() as tracer:
        _, on = serve_mix(registry=met.MetricsRegistry())
        names = {r["name"] for r in tracer.export() if r["type"] == "span"}
    # the serving lifecycle is spanned...
    assert {"service.batch", "service.request", "sptrsv.analyse",
            "sptrsv.solve"} <= names
    # ...and never enters compiled code: served panels are bit-identical
    assert len(off) == len(on)
    for x_off, x_on in zip(off, on):
        np.testing.assert_array_equal(x_off, x_on)


def test_service_batch_spans_parent_request_spans(tmp_path):
    path = str(tmp_path / "serve.jsonl")
    with tr.trace_to(path):
        serve_mix(registry=met.MetricsRegistry())
    recs = [json.loads(line) for line in open(path)]
    spans = [r for r in recs if r["type"] == "span"]
    batches = [r for r in spans if r["name"] == "service.batch"]
    requests = [r for r in spans if r["name"] == "service.request"]
    assert batches and requests
    # every batch span carries the admission attrs; width <= padded width
    for b in batches:
        assert b["attrs"]["n_requests"] >= 1
        assert b["attrs"]["width"] <= b["attrs"]["padded_width"]
    assert sum(b["attrs"]["n_requests"] for b in batches) == len(requests)
    for r in requests:
        assert r["attrs"]["latency_us"] > 0


def test_dispatch_stats_surfaces_compile_us():
    a, b = small_problem(n=80, levels=5)
    opts = PlanOptions(sched="auto", comm="zerocopy", kernel="reference",
                       block_size=16, probe_solves=1)
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts)
    h = ctx.analyse(a)
    auto = ctx.dispatch_stats(h)["auto"]
    assert set(auto["compile_us"]) == set(auto["probe_us"])
    assert all(us > 0 for us in auto["compile_us"].values())
    snap = ctx.metrics_snapshot(h)
    assert any(k.startswith("auto.compile_us.") for k in snap)
