import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from strategies import random_blocks as _blocks
from repro.core.blocking import build_blocks
from repro.core.partition import cut_stats, make_partition


@given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_taskpool_round_robin_properties(D, tpd, seed):
    bs = _blocks(seed=seed)
    part = make_partition(bs, D, "taskpool", tpd)
    assert part.owner.shape == (bs.nb,)
    assert part.owner.min() >= 0 and part.owner.max() < D
    # round-robin deal: consecutive tasks go to consecutive devices
    n_tasks = D * tpd
    task_size = max(1, -(-bs.nb // n_tasks))
    task_of = np.arange(bs.nb) // task_size
    assert np.array_equal(part.owner, task_of % D)
    # every device owns a non-empty share when there are enough tasks
    if bs.nb >= D * task_size:
        assert len(np.unique(part.owner)) == D


def test_contiguous_is_unidirectional():
    """Paper §V: with contiguous partitioning, updates only flow low->high device."""
    bs = _blocks()
    part = make_partition(bs, 4, "contiguous")
    src_dev = part.owner[bs.off_cols]
    dst_dev = part.owner[bs.off_rows]
    assert (dst_dev >= src_dev).all()


def test_boundary_definition():
    bs = _blocks()
    part = make_partition(bs, 4, "taskpool", 4)
    remote = part.owner[bs.off_cols] != part.owner[bs.off_rows]
    expect = np.zeros(bs.nb, bool)
    expect[bs.off_rows[remote]] = True
    assert np.array_equal(part.boundary, expect)


def test_taskpool_improves_level_balance_on_wide_matrix():
    """The paper's Fig 7 mechanism: round-robin balances per-level row counts."""
    from repro.sparse.suite import random_levelled

    a = random_levelled(1500, 8, 3.0, seed=2)
    bs = build_blocks(a, 4)
    tp = cut_stats(bs, make_partition(bs, 4, "taskpool", 8))
    ct = cut_stats(bs, make_partition(bs, 4, "contiguous"))
    assert tp.level_imbalance <= ct.level_imbalance + 1e-9
