"""Persistent plan store: cross-session reuse of the symbolic analysis.

Covers the ISSUE-9 plan-persistence points: serialize -> load -> solve is
bit-identical to the fresh-analysis plan across sched x comm x kernel x
transpose (dyadic exactness makes ``assert_array_equal`` real bit-equality),
corrupt or stale entries are rejected by the strict load-time verifier and
fall back to a fresh analysis without crashing, writes are atomic, and a
warm-started worker serves a multi-pattern mix with ZERO symbolic analyses
(the acceptance criterion, asserted via session counters).
"""
import json
import os
import zipfile

import numpy as np
import pytest

import strategies as st
from repro.api import PlanOptions, SpTRSVContext
from repro.obs import metrics as met
from repro.service import PlanStore, options_signature
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


def exact_problem(n=120, levels=6, seed=3):
    a = st.dyadic(suite.random_levelled(n, levels, 4.0, seed=seed))
    b = st.dyadic_rhs(a.n, seed=seed + 1)
    return a, b


def make_store(tmp_path, **kw):
    kw.setdefault("registry", met.MetricsRegistry())
    return PlanStore(str(tmp_path / "plans"), **kw)


def cold_then_warm(tmp_path, opts, a, b, *, transpose=False):
    """Two sessions against one store dir; returns (x_cold, x_warm, warm_ctx)."""
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts,
                        registry=met.MetricsRegistry(),
                        plan_store=make_store(tmp_path))
    h = ctx.analyse(a)
    if transpose:
        # materialize + persist the forward plan too (the typical L / L^T
        # pairing): the warm session's symbolic analysis loads from it
        ctx.plan(h)
    x_cold = np.asarray(ctx.solve(h, b, transpose=transpose))
    store = make_store(tmp_path)
    ctx2 = SpTRSVContext(mesh=st.mesh1(), options=opts,
                         registry=met.MetricsRegistry(), plan_store=store)
    h2 = ctx2.analyse(a)
    x_warm = np.asarray(ctx2.solve(h2, b, transpose=transpose))
    return x_cold, x_warm, ctx2


# ---------------------------------------------------------------------------
# keying
# ---------------------------------------------------------------------------


def test_options_signature_stable_and_sensitive():
    o = PlanOptions(block_size=16, sched="levelset")
    assert options_signature(o, 2) == options_signature(
        PlanOptions(block_size=16, sched="levelset"), 2)
    # every plan-shaping dimension separates entries
    assert options_signature(o, 2) != options_signature(o, 4)
    assert options_signature(o, 2) != options_signature(o, 2, transpose=True)
    assert options_signature(o, 2) != options_signature(
        PlanOptions(block_size=8, sched="levelset"), 2)
    assert options_signature(o, 2) != options_signature(
        PlanOptions(block_size=16, sched="dagpart"), 2)
    # check-only knobs never invalidate a stored plan
    assert options_signature(o, 2) == options_signature(
        PlanOptions(block_size=16, sched="levelset", verify="strict",
                    probe_solves=3), 2)


# ---------------------------------------------------------------------------
# round trip: serialize -> load -> solve bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched,comm,kernel,transpose", [
    ("levelset", "zerocopy", "default", False),
    ("levelset", "unified", "reference", True),
    ("dagpart", "zerocopy", "fused", False),
    ("syncfree", "zerocopy", "default", True),
])
def test_roundtrip_bit_identical(tmp_path, sched, comm, kernel, transpose):
    a, b = exact_problem()
    assert st.exactness_holds(a, b)
    opts = PlanOptions(block_size=16, sched=sched, comm=comm, kernel=kernel)
    x_cold, x_warm, ctx2 = cold_then_warm(tmp_path, opts, a, b,
                                          transpose=transpose)
    np.testing.assert_array_equal(x_cold, x_warm)
    s = ctx2.stats()
    assert s.get("analyses", 0) == 0, "warm session re-ran symbolic analysis"
    assert s["plan_store_hits"] >= 1
    if not transpose:
        np.testing.assert_array_equal(
            x_warm, reference_solve(a, b).astype(np.float32))


def test_roundtrip_covers_transpose_extension(tmp_path):
    """Both sweep directions of one analysis persist and reload: the warm
    L^T solve is a store hit, not a fresh transpose schedule build."""
    a, b = exact_problem()
    opts = PlanOptions(block_size=16)
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts,
                        registry=met.MetricsRegistry(),
                        plan_store=make_store(tmp_path))
    h = ctx.analyse(a)
    xf = np.asarray(ctx.solve(h, b))
    xt = np.asarray(ctx.solve(h, b, transpose=True))
    ctx2 = SpTRSVContext(mesh=st.mesh1(), options=opts,
                         registry=met.MetricsRegistry(),
                         plan_store=make_store(tmp_path))
    h2 = ctx2.analyse(a)
    np.testing.assert_array_equal(np.asarray(ctx2.solve(h2, b)), xf)
    np.testing.assert_array_equal(
        np.asarray(ctx2.solve(h2, b, transpose=True)), xt)
    s = ctx2.stats()
    assert s.get("analyses", 0) == 0
    assert s.get("transpose_extensions", 0) == 0
    assert s["plan_store_hits"] == 2  # forward + transpose both loaded


def test_auto_session_warm_starts_under_auto_key(tmp_path):
    """A cold auto session persists its resolved choice; the warm session
    loads it under the same auto signature — no re-tuning, no analysis."""
    a, b = exact_problem(n=80, levels=5)
    opts = PlanOptions(block_size=16, sched="auto", comm="zerocopy",
                       kernel="reference")
    x_cold, x_warm, ctx2 = cold_then_warm(tmp_path, opts, a, b)
    np.testing.assert_array_equal(x_cold, x_warm)
    s = ctx2.stats()
    assert s.get("analyses", 0) == 0 and s["plan_store_hits"] == 1


def test_values_rehydrate_from_caller_matrix(tmp_path):
    """The store holds no numeric values: a warm load against refreshed
    values solves with THOSE values (same pattern, different answer)."""
    a, b = exact_problem()
    a2 = st.dyadic(a, seed=99)  # same pattern, different values
    opts = PlanOptions(block_size=16)
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts,
                        registry=met.MetricsRegistry(),
                        plan_store=make_store(tmp_path))
    ctx.solve(ctx.analyse(a), b)
    ctx2 = SpTRSVContext(mesh=st.mesh1(), options=opts,
                         registry=met.MetricsRegistry(),
                         plan_store=make_store(tmp_path))
    x2 = np.asarray(ctx2.solve(ctx2.analyse(a2), b))
    assert ctx2.stats().get("analyses", 0) == 0
    np.testing.assert_array_equal(x2, reference_solve(a2, b).astype(np.float32))


# ---------------------------------------------------------------------------
# corruption / staleness: strict verifier rejects, store falls back cleanly
# ---------------------------------------------------------------------------


def populated_store(tmp_path, a, b, opts):
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts,
                        registry=met.MetricsRegistry(),
                        plan_store=make_store(tmp_path))
    ctx.solve(ctx.analyse(a), b)
    paths = [os.path.join(str(tmp_path / "plans"), f)
             for f in sorted(os.listdir(str(tmp_path / "plans")))]
    assert len(paths) == 1 and paths[0].endswith(".plan.npz")
    return paths[0]


def rewrite_npz(path, *, meta_patch=None, array_patch=None):
    """Round-trip the npz with a targeted mutation (a tampering 'attacker')."""
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(str(arrays["meta"][()]))
    if meta_patch:
        meta.update(meta_patch)
    arrays["meta"] = np.array(json.dumps(meta))
    if array_patch:
        for k, fn in array_patch.items():
            arrays[k] = fn(arrays[k])
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def assert_falls_back(tmp_path, a, b, opts, expect_rejected=True):
    """A defective entry must yield a fresh-analysis session that still
    solves correctly — and counts the rejection, not a crash."""
    store = make_store(tmp_path)
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts,
                        registry=met.MetricsRegistry(), plan_store=store)
    h = ctx.analyse(a)
    x = np.asarray(ctx.solve(h, b))
    np.testing.assert_array_equal(x, reference_solve(a, b).astype(np.float32))
    s = ctx.stats()
    assert s["analyses"] == 1 and s.get("plan_store_hits", 0) == 0
    if expect_rejected:
        assert store.stats["rejected"] == 1
    return store


def test_truncated_file_rejected(tmp_path):
    a, b = exact_problem()
    opts = PlanOptions(block_size=16)
    path = populated_store(tmp_path, a, b, opts)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 3])
    assert_falls_back(tmp_path, a, b, opts)


def test_wrong_version_header_rejected(tmp_path):
    a, b = exact_problem()
    opts = PlanOptions(block_size=16)
    path = populated_store(tmp_path, a, b, opts)
    rewrite_npz(path, meta_patch={"version": 999})
    assert_falls_back(tmp_path, a, b, opts)
    rewrite_npz(path, meta_patch={"format": "not-a-plan", "version": 1})
    assert_falls_back(tmp_path, a, b, opts)


def test_mutated_schedule_table_rejected_by_strict_verifier(tmp_path):
    """A tampered schedule that still parses must die at ``verify_plan``:
    reversing the compacted solve-row order breaks happens-before."""
    a, b = exact_problem()
    opts = PlanOptions(block_size=16)
    path = populated_store(tmp_path, a, b, opts)
    rewrite_npz(path,
                array_patch={"solve_rows": lambda v: v[..., ::-1].copy()})
    assert_falls_back(tmp_path, a, b, opts)


def test_zipfile_garbage_rejected(tmp_path):
    a, b = exact_problem()
    opts = PlanOptions(block_size=16)
    path = populated_store(tmp_path, a, b, opts)
    with zipfile.ZipFile(path, "w") as zf:  # valid zip, not a plan
        zf.writestr("meta", "garbage")
    assert_falls_back(tmp_path, a, b, opts)


def test_atomic_save_leaves_no_temp_files(tmp_path):
    a, b = exact_problem()
    opts = PlanOptions(block_size=16)
    populated_store(tmp_path, a, b, opts)
    leftovers = [f for f in os.listdir(str(tmp_path / "plans"))
                 if not f.endswith(".plan.npz")]
    assert leftovers == []


def test_unwritable_store_degrades_to_no_persistence(tmp_path, monkeypatch):
    """A store the worker cannot write to (read-only volume, disk full) must
    cost nothing but the saves — the session keeps solving."""
    a, b = exact_problem()
    opts = PlanOptions(block_size=16)
    store = make_store(tmp_path)

    def refuse(*args, **kwargs):
        raise OSError("read-only file system")

    monkeypatch.setattr(store, "save", refuse)
    ctx = SpTRSVContext(mesh=st.mesh1(), options=opts,
                        registry=met.MetricsRegistry(), plan_store=store)
    x = np.asarray(ctx.solve(ctx.analyse(a), b))
    np.testing.assert_array_equal(x, reference_solve(a, b).astype(np.float32))
    assert ctx.stats()["plan_store_save_errors"] == 1
    assert store.stats.get("saves", 0) == 0


# ---------------------------------------------------------------------------
# acceptance: warm worker serves a 3-pattern mix with zero symbolic analyses
# ---------------------------------------------------------------------------


def test_warm_worker_serves_mix_with_zero_analyses(tmp_path):
    patterns = [st.dyadic(suite.random_levelled(n, 6, 3.0, seed=s))
                for n, s in ((120, 1), (90, 2), (70, 3))]
    opts = PlanOptions(block_size=16)
    cold = SpTRSVContext(mesh=st.mesh1(), options=opts,
                         registry=met.MetricsRegistry(),
                         plan_store=make_store(tmp_path))
    for a in patterns:
        cold.solve(cold.analyse(a), st.dyadic_rhs(a.n))
    assert cold.stats()["analyses"] == len(patterns)

    store = make_store(tmp_path)
    assert store.verify == "strict"  # every load below is strict-verified
    warm = SpTRSVContext(mesh=st.mesh1(), options=opts,
                         registry=met.MetricsRegistry(), plan_store=store)
    # hot/cold mix: pattern 0 hammered, the tail touched once each
    for a in (patterns[0], patterns[1], patterns[0], patterns[2], patterns[0]):
        x = np.asarray(warm.solve(warm.analyse(a), st.dyadic_rhs(a.n)))
        np.testing.assert_array_equal(
            x, reference_solve(a, st.dyadic_rhs(a.n)).astype(np.float32))
    s = warm.stats()
    assert s.get("analyses", 0) == 0, "warm worker ran a symbolic analysis"
    assert s["plan_store_hits"] == len(patterns)
    assert store.stats["hits"] == len(patterns)
    assert store.stats.get("rejected", 0) == 0
    assert store.stats["hit_rate"] == 1.0
