"""Numeric IC(0)/ILU(0) factorization + transpose-solve correctness."""
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro import compat
from repro.core import DistributedSolver, SolverConfig, build_plan, sptrsv
from repro.krylov.precond import (
    ic0,
    ilu0,
    spd_lower_from_triangular,
    symmetric_full_csr,
    upper_as_reversed_lower,
)
from repro.sparse import suite
from repro.sparse.matrix import CSR, csr_transpose, reverse_transpose, to_scipy


def _mesh1():
    return compat.make_mesh((1,), ("x",))


def _spd_lower(side=14, seed=0):
    return spd_lower_from_triangular(suite.grid2d_factor(side, seed=seed))


def _dense_sym(a_lower):
    return to_scipy(symmetric_full_csr(a_lower)).toarray()


# ---------------------------------------------------------------------------
# factorizations
# ---------------------------------------------------------------------------


def test_ic0_equals_cholesky_on_full_pattern():
    """With a dense lower pattern IC(0) has nothing to drop -> exact Cholesky."""
    rng = np.random.default_rng(0)
    n = 24
    m = rng.uniform(-1, 1, (n, n))
    rows, cols = np.tril_indices(n, -1)
    tri = CSR(
        n=n,
        row_ptr=np.concatenate([[0], np.cumsum(np.arange(1, n + 1))]).astype(np.int64),
        col_idx=np.concatenate([np.arange(i + 1) for i in range(n)]).astype(np.int32),
        val=np.concatenate([np.append(m[i, :i], 1.0) for i in range(n)]),
    )
    a = spd_lower_from_triangular(tri)
    L = ic0(a)
    L_exact = np.linalg.cholesky(_dense_sym(a))
    np.testing.assert_allclose(to_scipy(L).toarray(), L_exact, rtol=1e-10, atol=1e-10)


def test_ic0_preserves_pattern_and_residual_on_pattern():
    a = _spd_lower()
    L = ic0(a)
    np.testing.assert_array_equal(L.row_ptr, a.row_ptr)
    np.testing.assert_array_equal(L.col_idx, a.col_idx)
    # defining property of IC(0): (L L^T)_ij = A_ij on the pattern of A
    Ld = to_scipy(L).toarray()
    prod = Ld @ Ld.T
    A = _dense_sym(a)
    rows = np.repeat(np.arange(a.n), np.diff(a.row_ptr))
    np.testing.assert_allclose(prod[rows, a.col_idx], A[rows, a.col_idx],
                               rtol=1e-8, atol=1e-8)


def test_ilu0_exact_lu_on_full_pattern():
    rng = np.random.default_rng(1)
    n = 20
    A = rng.uniform(-1, 1, (n, n)) + n * np.eye(n)
    rp = np.arange(0, n * n + 1, n, dtype=np.int64)
    ci = np.tile(np.arange(n, dtype=np.int32), n)
    lower, upper = ilu0(CSR(n=n, row_ptr=rp, col_idx=ci, val=A.reshape(-1).copy()))
    Ld, Ud = to_scipy(lower).toarray(), to_scipy(upper).toarray()
    np.testing.assert_allclose(Ld @ Ud, A, rtol=1e-9, atol=1e-9)
    assert np.allclose(np.diag(Ld), 1.0)


def test_ilu0_residual_vanishes_on_pattern():
    a_full = symmetric_full_csr(_spd_lower())
    lower, upper = ilu0(a_full)
    resid = to_scipy(lower).toarray() @ to_scipy(upper).toarray() - to_scipy(a_full).toarray()
    rows = np.repeat(np.arange(a_full.n), np.diff(a_full.row_ptr))
    np.testing.assert_allclose(resid[rows, a_full.col_idx], 0.0, atol=1e-8)


def test_spd_lower_is_spd():
    A = _dense_sym(_spd_lower())
    assert np.allclose(A, A.T)
    assert np.linalg.eigvalsh(A).min() > 0


def test_ilu0_zero_pivot_breakdown_regression():
    """A pattern whose elimination produces an exactly-zero pivot: the clamp
    must be written back into U, so U's diagonal stays nonzero and the
    transpose-plan U-solve stays finite (it used to divide by zero)."""
    # A = [[1,1,0],[1,1,1],[0,1,1]]: eliminating row 1 gives U[1,1] = 0, which
    # row 2 then uses as its pivot.
    A = np.array([[1.0, 1.0, 0.0],
                  [1.0, 1.0, 1.0],
                  [0.0, 1.0, 1.0]])
    nz = A != 0
    rp = np.concatenate([[0], np.cumsum(nz.sum(1))]).astype(np.int64)
    ci = np.concatenate([np.nonzero(nz[i])[0] for i in range(3)]).astype(np.int32)
    a = CSR(n=3, row_ptr=rp, col_idx=ci, val=A[nz].astype(np.float64))
    lower, upper = ilu0(a)
    u_diag = upper.val[upper.row_ptr[:-1]]  # upper CSR: diagonal entry first
    assert np.all(u_diag != 0.0), "clamped pivot must be written back"
    assert np.all(np.isfinite(lower.val)) and np.all(np.isfinite(upper.val))
    # the real downstream consumer: U x = y through the transpose-plan solver
    y = np.array([1.0, 2.0, 3.0])
    plan = build_plan(upper_as_reversed_lower(upper), 1,
                      SolverConfig(block_size=4), transpose=True)
    x = DistributedSolver(plan, _mesh1()).solve(y)
    assert np.all(np.isfinite(x))


def test_ilu0_trailing_zero_pivot_clamped():
    """A zero pivot on the LAST row is never used by a later elimination — it
    must still be clamped so U's diagonal is nonzero."""
    A = np.array([[1.0, 1.0],
                  [1.0, 1.0]])
    rp = np.array([0, 2, 4], np.int64)
    ci = np.array([0, 1, 0, 1], np.int32)
    _, upper = ilu0(CSR(n=2, row_ptr=rp, col_idx=ci, val=A.reshape(-1).copy()))
    assert np.all(upper.val[upper.row_ptr[:-1]] != 0.0)


# ---------------------------------------------------------------------------
# transpose / upper-triangular solves through the distributed solver
# ---------------------------------------------------------------------------


def test_reverse_transpose_roundtrip():
    a = suite.random_levelled(200, 16, 3.0, seed=7)
    rt = reverse_transpose(a)
    assert np.all(rt.col_idx <= np.repeat(np.arange(a.n), np.diff(rt.row_ptr)))
    np.testing.assert_allclose(
        to_scipy(reverse_transpose(rt)).toarray(), to_scipy(a).toarray()
    )


@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_transpose_solve_matches_scipy(sched):
    a = suite.grid2d_factor(16, seed=2)
    b = np.random.default_rng(3).uniform(-1, 1, a.n)
    cfg = SolverConfig(block_size=16, sched=sched)
    x = sptrsv(a, b, mesh=_mesh1(), config=cfg, transpose=True)
    x_ref = spla.spsolve_triangular(to_scipy(a).T.tocsr(), b, lower=False)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4)


def test_upper_solve_via_transpose_plan():
    """U x = y for the ILU(0) upper factor, executed as a transposed plan."""
    a_full = symmetric_full_csr(_spd_lower(side=10, seed=4))
    _, upper = ilu0(a_full)
    y = np.random.default_rng(5).uniform(-1, 1, a_full.n)
    plan = build_plan(upper_as_reversed_lower(upper), 1,
                      SolverConfig(block_size=8), transpose=True)
    solver = DistributedSolver(plan, _mesh1())
    x = solver.solve(y)
    x_ref = spla.spsolve_triangular(to_scipy(upper).tocsr(), y, lower=False)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4)


def test_csr_transpose_matches_scipy():
    a = suite.random_levelled(150, 12, 3.0, seed=8)
    np.testing.assert_allclose(
        to_scipy(csr_transpose(a)).toarray(), to_scipy(a).toarray().T
    )
