"""Property-test layer over the shared strategies (tests/strategies.py).

Where the named suites pin specific regimes, these properties sweep the
structure space: random levelled/banded triangular systems against the scipy
oracle, dyadic draws for executor bit-identity (switch vs fused vs
fused-streamed — the streaming HBM tile store must never change a bit), and
plan/partition invariants that every generated schedule must satisfy.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional (requirements-dev.txt)
from hypothesis import HealthCheck, assume, given, settings

import strategies
from repro.core import DistributedSolver, SolverConfig, build_plan
from repro.core.partition import make_partition
from repro.core.solver import fused_segments, level_widths
from repro.sparse.matrix import reference_solve

SETTINGS = dict(deadline=None, derandomize=True,
                suppress_health_check=[HealthCheck.too_slow,
                                       HealthCheck.filter_too_much,
                                       HealthCheck.data_too_large])


@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
@settings(max_examples=8, **SETTINGS)
@given(problem=strategies.triangular_problems())
def test_solver_matches_oracle(problem, sched):
    a, b = problem
    cfg = SolverConfig(block_size=16, sched=sched)
    x = DistributedSolver(build_plan(a, 1, cfg), strategies.mesh1()).solve(b)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)


@settings(max_examples=5, **SETTINGS)
@given(problem=strategies.dyadic_problems())
def test_executors_bit_identical_on_dyadic_draws(problem):
    """switch (pallas), fused, and fused_streamed all produce identical bits
    on any exact-arithmetic draw — the generated-structure version of the
    pinned EXACT_MATRICES comparisons."""
    a, b = problem
    # exactness is a property of the draw's depth/magnitudes, not of the
    # executors under test — skip the (rare) draws that round in float32
    assume(strategies.exactness_holds(a, b))
    mesh = strategies.mesh1()
    xs = {}
    for kb in ("pallas", "fused", "fused_streamed"):
        cfg = SolverConfig(block_size=16, kernel_backend=kb)
        xs[kb] = DistributedSolver(build_plan(a, 1, cfg), mesh).solve(b)
    np.testing.assert_array_equal(xs["pallas"], xs["fused"])
    np.testing.assert_array_equal(xs["fused"], xs["fused_streamed"])
    np.testing.assert_array_equal(xs["fused_streamed"], reference_solve(a, b))


@settings(max_examples=5, **SETTINGS)
@given(problem=strategies.dyadic_problems())
def test_dagpart_bit_identical_to_levelset_on_dyadic_draws(problem):
    """Merging supersteps must never change a bit: the dagpart plan (every
    kernel backend) reproduces the unmerged levelset switch executor exactly
    on exact-arithmetic draws, and the merged plan verifies strict."""
    from repro.verify import verify_plan

    a, b = problem
    assume(strategies.exactness_holds(a, b))
    mesh = strategies.mesh1()
    ref = DistributedSolver(
        build_plan(a, 1, SolverConfig(block_size=16)), mesh).solve(b)
    for kb in ("reference", "pallas", "fused", "fused_streamed"):
        cfg = SolverConfig(block_size=16, sched="dagpart", kernel_backend=kb)
        plan = build_plan(a, 1, cfg)
        assert verify_plan(plan, level="strict").passed
        x = DistributedSolver(plan, mesh).solve(b)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(x))


@settings(max_examples=15, **SETTINGS)
@given(problem=strategies.triangular_problems(max_n=200))
def test_plan_schedule_invariants(problem):
    """Every generated plan satisfies the compacted-schedule contract:
    offsets partition the flats at bucket widths, every row is scheduled
    exactly once, and the fused segments tile [0, T) in order."""
    a, _ = problem
    plan = build_plan(a, 4, SolverConfig(block_size=8))
    wid = level_widths(plan)
    T = plan.n_levels
    assert wid.shape == (T, 3)
    np.testing.assert_array_equal(
        plan.lvl_off[:, 0], np.concatenate([[0], np.cumsum(wid[:-1, 0])]))
    owned = np.concatenate(
        [plan.solve_rows[d][plan.solve_rows[d] >= 0] for d in range(4)])
    np.testing.assert_array_equal(np.sort(owned), np.arange(plan.bs.nb))
    segs = fused_segments(plan)
    assert segs[0, 0] == 0 and segs[-1, 1] == T
    np.testing.assert_array_equal(segs[1:, 0], segs[:-1, 1])


@pytest.mark.parametrize("strategy", ["taskpool", "contiguous", "malleable"])
@settings(max_examples=20, **SETTINGS)
@given(bs=strategies.block_structures())
def test_partition_invariants(bs, strategy):
    """Ownership/boundary invariants hold for every strategy on every
    generated block structure (extends the taskpool-only property)."""
    part = make_partition(bs, 4, strategy, 4)
    assert part.owner.shape == (bs.nb,)
    assert part.owner.min() >= 0 and part.owner.max() < 4
    remote = part.owner[bs.off_cols] != part.owner[bs.off_rows]
    expect = np.zeros(bs.nb, bool)
    expect[bs.off_rows[remote]] = True
    assert np.array_equal(part.boundary, expect)


@pytest.mark.parametrize("sched,comm", [("levelset", "zerocopy"),
                                        ("levelset", "unified"),
                                        ("dagpart", "zerocopy"),
                                        ("dagpart", "unified"),
                                        ("syncfree", "zerocopy"),
                                        ("syncfree", "unified")])
@pytest.mark.parametrize("transpose", [False, True])
@settings(max_examples=10, **SETTINGS)
@given(problem=strategies.triangular_problems(max_n=200))
def test_generated_plans_verify_strict(problem, sched, comm, transpose):
    """Every plan the builders produce from a generated structure passes the
    static verifier at the strictest level — happens-before over the
    compacted schedules plus the kernel-contract lint, for every sched x comm
    combination, forward and transposed (ISSUE 7: the property version of the
    pinned mutation fixtures in tests/test_verify.py)."""
    from repro.verify import verify_plan

    a, _ = problem
    for D in (1, 4):
        cfg = SolverConfig(block_size=8, sched=sched, comm=comm,
                           partition="malleable")
        report = verify_plan(build_plan(a, D, cfg, transpose=transpose),
                             level="strict")
        assert report.passed, "\n".join(str(f) for f in report.findings)
