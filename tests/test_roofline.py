"""Roofline accounting: flops calibration + HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.roofline import FMA_FACTOR, roofline_row
from repro import compat
from repro.launch.dryrun import collective_bytes


def test_xla_cpu_flops_convention():
    """cost_analysis counts 2NMK for a matmul — FMA_FACTOR must match."""
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
    flops = compat.cost_analysis(c)["flops"]
    assert abs(flops * FMA_FACTOR - 2 * 256**3) / (2 * 256**3) < 0.05


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[2048]{0} all-gather(%y), dimensions={0}
  %rs.5 = (f32[64,64]{1,0}, f32[64,64]{1,0}) reduce-scatter(%a, %b), dims={0}
  %cp = u32[16]{0} collective-permute-start(%c), pairs={{0,1}}
  %notacoll = f32[8,8]{1,0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    b = out["bytes"]
    assert b["all-reduce"] == 1024 * 512 * 4
    assert b["all-gather"] == 2048 * 2
    assert b["reduce-scatter"] == 2 * 64 * 64 * 4
    assert b["collective-permute"] == 16 * 4
    assert b["total"] == sum(v for k, v in b.items() if k != "total")


def test_roofline_row_math():
    ag = 50e9 / 4  # payload; wire = payload * 15/16 for all-gather
    rec = {
        "status": "ok", "arch": "a", "shape": "s", "mesh": "single",
        "n_devices": 256,
        "flops_per_device": 197e12,  # exactly 1s of compute
        "bytes_per_device": 819e9 / 2,  # 0.5s of HBM
        "collectives": {"bytes": {"all-gather": ag, "total": ag}},
        "model_flops": 197e12 * 256 * FMA_FACTOR * 0.5,
        "memory": {"temp_size_in_bytes": 0},
    }
    r = roofline_row(rec)
    assert r["bottleneck"] == "compute"
    assert abs(r["compute_s"] - 1.0) < 1e-6
    assert abs(r["memory_s"] - 0.5) < 1e-6
    assert abs(r["collective_s"] - 0.25 * 15 / 16) < 1e-6
    assert abs(r["useful_flops_ratio"] - 0.5) < 1e-6
    assert r["roofline_fraction"] == 1.0


def test_wire_bytes_factors():
    from benchmarks.roofline import wire_bytes

    coll = {"all-reduce": 16.0, "all-gather": 16.0, "total": 32.0}
    # AR: 2*(15/16)*16 = 30; AG: (15/16)*16 = 15
    assert abs(wire_bytes(coll, ring=16) - 45.0) < 1e-9
