"""Serving layer: admission queue, coalescing engine, bounded session caches.

Queue semantics (grouping by pattern x value fingerprint, the RHS pad
ladder, per-tenant fairness, bounded-queue backpressure), the engine's
end-to-end batched correctness against the scipy oracle (coalesced panels
scatter back bit-exactly per request, including the hot-pattern value
refresh), the threaded serve loop, error routing to tickets, and the ISSUE-9
LRU satellite: ``cache_capacity`` evicts least-recently-used compiled
handles with a ``session.evictions`` counter.
"""
import threading

import numpy as np
import pytest

import strategies as st
from repro.api import PlanOptions, SpTRSVContext
from repro.obs import metrics as met
from repro.service import QueueFull, SolveEngine, SolveQueue
from repro.service.queue import pad_width, rhs_ladder, value_key
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


def exact(n=96, levels=5, seed=1):
    return st.dyadic(suite.random_levelled(n, levels, 3.0, seed=seed))


def make_engine(**kw):
    kw.setdefault("mesh", st.mesh1())
    kw.setdefault("options", PlanOptions(block_size=16))
    kw.setdefault("registry", met.MetricsRegistry())
    return SolveEngine(**kw)


# ---------------------------------------------------------------------------
# queue: ladder, grouping, fairness, backpressure
# ---------------------------------------------------------------------------


def test_rhs_ladder_and_pad_width():
    assert rhs_ladder(8) == (1, 2, 4, 8)
    assert rhs_ladder(6) == (1, 2, 4, 6)
    assert rhs_ladder(1) == (1,)
    lad = rhs_ladder(8)
    assert [pad_width(lad, r) for r in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]


def test_groups_split_by_pattern_values_and_direction():
    a = exact(seed=1)
    a_vals = st.dyadic(a, seed=9)  # same pattern, different values
    c = exact(n=64, seed=2)  # different pattern
    q = SolveQueue(max_batch=8)
    b = np.ones(a.n, np.float32)
    reqs = [q.submit("t", a, b), q.submit("t", a_vals, b),
            q.submit("t", c, np.ones(c.n, np.float32)),
            q.submit("t", a, b, transpose=True), q.submit("t", a, b)]
    groups = {t.request.group for t in reqs}
    assert len(groups) == 4  # (a), (a new vals), (c), (a transposed)
    assert reqs[0].request.group == reqs[4].request.group
    assert value_key(a) != value_key(a_vals)
    # one batch holds exactly one group: the two same-value `a` requests
    batch = q.next_batch(force=True)
    assert sorted(t.request.id for t in batch) == [0, 4]


def test_fairness_round_robin_across_tenants():
    a = exact()
    q = SolveQueue(max_batch=4)
    b = np.ones(a.n, np.float32)
    for i in range(6):
        q.submit("hog", a, b)  # ids 0..5
    q.submit("quiet", a, b)  # id 6
    batch = q.next_batch(force=True)
    ids = [t.request.id for t in batch]
    # the quiet tenant's single request is admitted ahead of the hog's tail
    assert 6 in ids and len(ids) == 4
    rest = q.next_batch(force=True)
    assert len(rest) == 3 and q.depth == 0


def test_admission_window_and_force():
    a = exact()
    q = SolveQueue(max_batch=4, max_wait_s=60.0)
    b = np.ones(a.n, np.float32)
    q.submit("t", a, b)
    assert q.next_batch() is None  # 1 < max_batch and nobody waited 60s
    for _ in range(3):
        q.submit("t", a, b)
    assert len(q.next_batch()) == 4  # full batch dispatches immediately
    q.submit("t", a, b)
    assert q.next_batch() is None
    assert len(q.next_batch(force=True)) == 1  # drain path ignores the window


def test_backpressure_queue_full():
    a = exact()
    q = SolveQueue(max_batch=2, max_pending=3)
    b = np.ones(a.n, np.float32)
    q.submit("t", a, b)
    q.submit("t", a, np.ones((a.n, 2), np.float32))  # panel: 2 columns
    with pytest.raises(QueueFull):
        q.submit("t", a, b)
    q.next_batch(force=True)
    q.submit("t", a, b)  # drained capacity is reusable


def test_oversized_panel_admitted_alone():
    a = exact()
    q = SolveQueue(max_batch=2)
    q.submit("t", a, np.ones((a.n, 5), np.float32))
    batch = q.next_batch(force=True)
    assert len(batch) == 1 and batch[0].request.n_columns == 5
    assert q.depth == 0


def test_coalesce_scatter_roundtrip_mixed_shapes():
    a = exact()
    q = SolveQueue(max_batch=8)
    t1 = q.submit("t", a, np.full(a.n, 1, np.float32))
    t2 = q.submit("t", a, np.arange(2 * a.n, dtype=np.float32).reshape(a.n, 2))
    t3 = q.submit("t", a, np.full(a.n, 3, np.float32))
    batch = q.next_batch(force=True)
    panel, r = q.coalesce(batch)
    assert r == 4 and panel.shape == (a.n, 4)  # ladder pad 4 -> 4 (exact)
    q.scatter(batch, panel)  # identity "solve": inputs come back verbatim
    np.testing.assert_array_equal(t1.result(0), np.full(a.n, 1, np.float32))
    assert t2.result(0).shape == (a.n, 2)
    np.testing.assert_array_equal(t3.result(0), np.full(a.n, 3, np.float32))


# ---------------------------------------------------------------------------
# engine: batched correctness, refresh, errors, threading
# ---------------------------------------------------------------------------


def test_engine_serves_mix_correctly_and_counts():
    mats = [exact(seed=1), exact(n=64, seed=2), exact(n=48, seed=3)]
    eng = make_engine(max_batch=4)
    rng = np.random.default_rng(0)
    tickets = []
    for i in range(10):
        m = mats[i % 3 if i % 2 else 0]
        tickets.append(eng.submit(f"t{i % 2}", m,
                                  rng.integers(-4, 5, m.n).astype(np.float32)))
    assert eng.drain() == 10
    for t in tickets:
        np.testing.assert_array_equal(
            np.asarray(t.result(0)),
            reference_solve(t.request.matrix,
                            t.request.rhs).astype(np.float32))
        assert t.done() and t.latency_s > 0
    s = eng.stats()
    assert s["requests"] == s["results"] == 10
    assert s["coalesced_columns"] == 10 and s["queue_depth"] == 0
    assert s["batches"] == s["solves"] and s["batches"] < 10  # real coalescing
    assert s["session"]["analyses"] == 3  # one per pattern, ever


def test_engine_hot_pattern_value_refresh_in_place():
    """New values on the hot pattern are a factorize, not a re-analysis, and
    the served results follow the new values."""
    a = exact(seed=1)
    a2 = st.dyadic(a, seed=7)
    eng = make_engine()
    b = st.dyadic_rhs(a.n)
    t1 = eng.submit("t", a, b)
    eng.drain()
    t2 = eng.submit("t", a2, b)
    eng.drain()
    np.testing.assert_array_equal(
        np.asarray(t1.result(0)), reference_solve(a, b).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(t2.result(0)), reference_solve(a2, b).astype(np.float32))
    sess = eng.stats()["session"]
    assert sess["analyses"] == 1 and sess["factorizes"] == 1


def test_engine_routes_solve_errors_to_tickets(monkeypatch):
    eng = make_engine()
    a = exact()

    def boom(*args, **kwargs):
        raise RuntimeError("device fell over")

    monkeypatch.setattr(eng.ctx, "solve", boom)
    t = eng.submit("t", a, np.ones(a.n, np.float32))
    assert eng.step() == 1  # the batch is consumed, not wedged
    with pytest.raises(RuntimeError, match="device fell over"):
        t.result(0)
    s = eng.stats()
    assert s["errors"] == 1 and s["queue_depth"] == 0
    assert s.get("results", 0) == 0


def test_engine_submit_shape_mismatch_raises():
    eng = make_engine()
    a = exact()
    with pytest.raises(ValueError, match="rhs shape"):
        eng.submit("t", a, np.ones(a.n + 1, np.float32))


def test_engine_background_thread_serves_blocking_tenants():
    a = exact()
    eng = make_engine(max_batch=4, max_wait_s=0.01)
    b = st.dyadic_rhs(a.n)
    results = {}

    def tenant(name):
        t = eng.submit(name, a, b)
        results[name] = np.asarray(t.result(timeout=30))

    with eng:
        threads = [threading.Thread(target=tenant, args=(f"t{i}",))
                   for i in range(6)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    ref = reference_solve(a, b).astype(np.float32)
    assert len(results) == 6
    for x in results.values():
        np.testing.assert_array_equal(x, ref)
    assert eng.stats()["queue_depth"] == 0
    with pytest.raises(RuntimeError, match="already started"):
        eng.start().start()
    eng.stop()


# ---------------------------------------------------------------------------
# LRU-bounded session caches (ISSUE-9 satellite)
# ---------------------------------------------------------------------------


def test_cache_capacity_evicts_lru_with_counter():
    mats = [exact(seed=s) for s in (1, 2, 3)]
    reg = met.MetricsRegistry()
    ctx = SpTRSVContext(mesh=st.mesh1(), options=PlanOptions(block_size=16),
                        registry=reg, cache_capacity=2)
    b = [st.dyadic_rhs(m.n) for m in mats]
    h0 = ctx.analyse(mats[0])
    ctx.solve(h0, b[0])
    ctx.solve(ctx.analyse(mats[1]), b[1])
    ctx.solve(h0, b[0])  # touch pattern 0: pattern 1 becomes the LRU entry
    ctx.solve(ctx.analyse(mats[2]), b[2])  # evicts pattern 1
    assert ctx.stats()["evictions"] == 1
    assert reg.snapshot()["session.evictions"] == 1
    assert len(ctx._entries) == 2
    # the survivor is still a cache hit; the evicted pattern re-enters
    # through the retained symbolic analysis (no new partition build)
    analyses = ctx.stats()["analyses"]
    ctx.analyse(mats[0])
    h1 = ctx.analyse(mats[1])
    ctx.solve(h1, b[1])
    s = ctx.stats()
    assert s["analyses"] == analyses  # symbolic cache absorbed the re-entry
    assert s["symbolic_hits"] >= 1 and s["evictions"] == 2


def test_cache_capacity_validation_and_unbounded_default():
    with pytest.raises(ValueError, match="cache_capacity"):
        SpTRSVContext(mesh=st.mesh1(), cache_capacity=0)
    ctx = SpTRSVContext(mesh=st.mesh1(), registry=met.MetricsRegistry())
    for s in (1, 2, 3):
        a = exact(n=48, seed=s)
        ctx.solve(ctx.analyse(a), st.dyadic_rhs(a.n))
    assert ctx.stats().get("evictions", 0) == 0  # None = unbounded


def test_engine_passes_capacity_through():
    eng = make_engine(cache_capacity=1)
    for s in (1, 2):
        a = exact(n=48, seed=s)
        eng.submit("t", a, np.ones(a.n, np.float32))
    eng.drain()
    assert eng.stats()["session"]["evictions"] == 1
