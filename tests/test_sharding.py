"""Sharding rule engine: every spec must divide its dim on the production mesh."""
import functools

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.distributed.sharding import batch_specs, cache_specs, param_specs
from repro.models import init_cache, init_params


class FakeMesh:
    """Shape-only stand-in so spec tests don't need 256 devices."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESHES = {
    "single": FakeMesh({"data": 16, "model": 16}),
    "multi": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _assert_divisible(tree, spec_tree, mesh, what):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(specs)
    for (path, leaf), spec in zip(leaves, specs):
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            size = _axis_size(mesh, axes)
            assert leaf.shape[dim] % size == 0, (
                what, jax.tree_util.keystr(path), leaf.shape, dim, spec)


@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divide(arch, mesh_kind):
    cfg = get_config(arch)
    mesh = MESHES[mesh_kind]
    params = jax.eval_shape(functools.partial(init_params, cfg), jax.random.key(0))
    dp = tuple(a for a in mesh.axis_names if a != "model")
    specs = param_specs(params, mesh, fsdp_axes=dp)
    _assert_divisible(params, specs, mesh, f"{arch} params")
    # at least the big 2D+ leaves must actually be sharded on some axis
    big = [
        (p, s) for (p, l), s in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        if np.prod(l.shape) >= (1 << 24)
        for p, s in [(jax.tree_util.keystr(p), s)]
    ]
    for pth, s in big:
        assert any(a is not None for a in s), (arch, pth)


@pytest.mark.parametrize("arch", ["zamba2-7b", "llama4-maverick-400b-a17b",
                                  "falcon-mamba-7b", "seamless-m4t-medium"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    mesh = MESHES["single"]
    for shape in ("decode_32k", "long_500k"):
        cell = SHAPES[shape]
        if shape == "long_500k" and not cfg.subquadratic:
            continue
        cache = jax.eval_shape(
            functools.partial(init_cache, cfg, cell.global_batch, cell.seq_len))
        specs = cache_specs(cache, mesh, dp_axes=("data",))
        _assert_divisible(cache, specs, mesh, f"{arch} cache {shape}")


def test_batch_specs_divide_and_fallback():
    mesh = MESHES["multi"]
    batch = {
        "tokens": jax.ShapeDtypeStruct((256, 4096), np.int32),
        "odd": jax.ShapeDtypeStruct((7, 3), np.float32),
    }
    specs = batch_specs(batch, mesh, dp_axes=("pod", "data"))
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["odd"] == P(None, None)
