"""SpTRSV end-to-end vs scipy oracle — all scheduling/comm/partition modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DistributedSolver, SolverConfig, build_plan, solve_local, sptrsv
from repro.core.blocking import pad_rhs, unpad_x
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


def _mesh1():
    return jax.make_mesh((1,), ("x",), devices=jax.devices()[:1],
                         axis_types=(jax.sharding.AxisType.Auto,))


MATRICES = {
    "levelled": lambda: suite.random_levelled(400, 24, 4.0, seed=3),
    "chain": lambda: suite.chain(150),
    "grid": lambda: suite.grid2d_factor(18, seed=1),
    "parallel": lambda: suite.block_diagonal_parallel(300, 12, 3.0, seed=2),
    "two_level": lambda: suite.random_levelled(300, 2, 8.0, seed=4),
}


@pytest.fixture(scope="module", params=list(MATRICES))
def problem(request):
    a = MATRICES[request.param]()
    b = np.random.default_rng(0).uniform(-1, 1, a.n)
    return a, b, reference_solve(a, b)


@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_all_modes_match_reference(problem, comm, sched):
    a, b, x_ref = problem
    cfg = SolverConfig(block_size=16, comm=comm, sched=sched)
    x = sptrsv(a, b, mesh=_mesh1(), config=cfg)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4)


def test_solve_local_matches_reference(problem):
    a, b, x_ref = problem
    plan = build_plan(a, 1, SolverConfig(block_size=8))
    xb = solve_local(plan, jnp.asarray(pad_rhs(b, plan.bs)))
    np.testing.assert_allclose(unpad_x(np.asarray(xb), plan.bs), x_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block_size", [4, 16, 64])
def test_block_size_invariance(block_size):
    a = MATRICES["levelled"]()
    b = np.random.default_rng(1).uniform(-1, 1, a.n)
    x = sptrsv(a, b, mesh=_mesh1(), config=SolverConfig(block_size=block_size))
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)


def test_solver_reuse_multiple_rhs():
    """Paper runs the solver 100x per matrix: plan/compile once, solve many."""
    a = MATRICES["grid"]()
    plan = build_plan(a, 1, SolverConfig(block_size=16))
    solver = DistributedSolver(plan, _mesh1())
    rng = np.random.default_rng(2)
    for _ in range(3):
        b = rng.uniform(-1, 1, a.n)
        np.testing.assert_allclose(solver.solve(b), reference_solve(a, b),
                                   rtol=2e-4, atol=2e-4)


def test_pallas_backend_end_to_end():
    """Whole solve with the Pallas kernels (interpret mode) instead of XLA ref."""
    a = suite.random_levelled(120, 10, 3.0, seed=5)
    b = np.random.default_rng(3).uniform(-1, 1, a.n)
    cfg = SolverConfig(block_size=16, kernel_backend="pallas")
    x = sptrsv(a, b, mesh=_mesh1(), config=cfg)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)


def test_comm_bytes_accounting():
    a = MATRICES["levelled"]()
    zc = build_plan(a, 4, SolverConfig(block_size=16, comm="zerocopy"))
    un = build_plan(a, 4, SolverConfig(block_size=16, comm="unified"))
    assert zc.comm_bytes_per_solve < un.comm_bytes_per_solve
