"""SpTRSV end-to-end vs scipy oracle — all scheduling/comm/partition modes.

Matrix generators live in ``tests/strategies.py`` (shared with the superstep,
malleable, and krylov suites).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from strategies import SOLVER_MATRICES as MATRICES, mesh1 as _mesh1
from repro.core import DistributedSolver, SolverConfig, build_plan, solve_local, sptrsv
from repro.core.blocking import pad_rhs, unpad_x
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


@pytest.fixture(scope="module", params=list(MATRICES))
def problem(request):
    a = MATRICES[request.param]()
    b = np.random.default_rng(0).uniform(-1, 1, a.n)
    return a, b, reference_solve(a, b)


@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_all_modes_match_reference(problem, comm, sched):
    a, b, x_ref = problem
    cfg = SolverConfig(block_size=16, comm=comm, sched=sched)
    x = sptrsv(a, b, mesh=_mesh1(), config=cfg)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4)


def test_solve_local_matches_reference(problem):
    a, b, x_ref = problem
    plan = build_plan(a, 1, SolverConfig(block_size=8))
    xb = solve_local(plan, jnp.asarray(pad_rhs(b, plan.bs)))
    np.testing.assert_allclose(unpad_x(np.asarray(xb), plan.bs), x_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block_size", [4, 16, 64])
def test_block_size_invariance(block_size):
    a = MATRICES["levelled"]()
    b = np.random.default_rng(1).uniform(-1, 1, a.n)
    x = sptrsv(a, b, mesh=_mesh1(), config=SolverConfig(block_size=block_size))
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)


def test_solver_reuse_multiple_rhs():
    """Paper runs the solver 100x per matrix: plan/compile once, solve many."""
    a = MATRICES["grid"]()
    plan = build_plan(a, 1, SolverConfig(block_size=16))
    solver = DistributedSolver(plan, _mesh1())
    rng = np.random.default_rng(2)
    for _ in range(3):
        b = rng.uniform(-1, 1, a.n)
        np.testing.assert_allclose(solver.solve(b), reference_solve(a, b),
                                   rtol=2e-4, atol=2e-4)


def test_pallas_backend_end_to_end():
    """Whole solve with the Pallas kernels (interpret mode) instead of XLA ref."""
    a = suite.random_levelled(120, 10, 3.0, seed=5)
    b = np.random.default_rng(3).uniform(-1, 1, a.n)
    cfg = SolverConfig(block_size=16, kernel_backend="pallas")
    x = sptrsv(a, b, mesh=_mesh1(), config=cfg)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)


def test_comm_bytes_accounting():
    a = MATRICES["levelled"]()
    zc = build_plan(a, 4, SolverConfig(block_size=16, comm="zerocopy"))
    un = build_plan(a, 4, SolverConfig(block_size=16, comm="unified"))
    assert zc.comm_bytes_per_solve < un.comm_bytes_per_solve


@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_comm_bytes_zero_on_single_device(comm, sched):
    """Single-device plans execute no collectives: the model must say 0 bytes
    (it used to count the sentinel pad slots of the exchange schedules)."""
    a = MATRICES["levelled"]()
    plan = build_plan(a, 1, SolverConfig(block_size=16, comm=comm, sched=sched))
    assert plan.comm_bytes_per_solve == 0


@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_comm_bytes_zero_when_no_boundary(sched):
    """A partition with an empty cut exchanges nothing under zerocopy, even on
    a multi-device plan — and the solver still matches the oracle."""
    a = suite.block_diagonal_parallel(512, 8, 3.0, seed=2)
    cfg = SolverConfig(block_size=16, comm="zerocopy", sched=sched,
                       partition="contiguous")
    plan = build_plan(a, 8, cfg)
    assert plan.n_boundary_rows == 0
    assert plan.comm_bytes_per_solve == 0


def test_comm_bytes_is_executed_exchange_payload():
    """Levelset/zerocopy volume = what the bucketed executor actually psums:
    at least one slot per real boundary row, but strictly below the old dense
    (T, max-width) sentinel-slot accounting."""
    a = MATRICES["levelled"]()
    plan = build_plan(a, 4, SolverConfig(block_size=16, comm="zerocopy"))
    assert plan.n_boundary_rows > 0
    widths = np.array(plan.buckets)[plan.lvl_bucket]
    assert plan.comm_bytes_per_solve == widths[:, 2].sum() * plan.bs.B * 4
    assert plan.comm_bytes_per_solve >= plan.n_boundary_rows * plan.bs.B * 4
    per_level = np.bincount(plan.bs.block_level[plan.part.boundary],
                            minlength=plan.n_levels)
    old_model = plan.n_levels * per_level.max() * plan.bs.B * 4
    assert plan.comm_bytes_per_solve < old_model


def test_compacted_schedules_beat_pad_to_max():
    """The ragged layout's total padded footprint must undercut the old dense
    (T, max-width) layout on a skewed level-size distribution."""
    a = suite.random_levelled(600, 40, 4.0, seed=6)
    plan = build_plan(a, 4, SolverConfig(block_size=16))
    T = plan.n_levels
    assert 1 <= len(plan.buckets) <= 12
    widths = np.array(plan.buckets)[plan.lvl_bucket]  # (T, 3) per-level widths
    for k, flat in ((0, plan.solve_rows), (1, plan.upd_tiles)):
        dense = T * widths[:, k].max()
        assert flat.shape[1] == max(1, widths[:, k].sum()) < dense
    # offsets partition the flats exactly
    np.testing.assert_array_equal(plan.lvl_off[:, 0],
                                  np.concatenate([[0], np.cumsum(widths[:-1, 0])]))
    # every real (non-pad) schedule entry survives compaction
    owned = [np.sort(plan.solve_rows[d][plan.solve_rows[d] >= 0]) for d in range(4)]
    np.testing.assert_array_equal(np.sort(np.concatenate(owned)), np.arange(plan.bs.nb))


def test_comm_bytes_syncfree_counts_counter_traffic():
    """Syncfree/unified psums in-degree counters on top of the accumulators —
    its predicted volume must exceed levelset/unified on the same matrix."""
    a = MATRICES["levelled"]()
    lv = build_plan(a, 4, SolverConfig(block_size=16, comm="unified", sched="levelset"))
    sf = build_plan(a, 4, SolverConfig(block_size=16, comm="unified", sched="syncfree"))
    assert sf.comm_bytes_per_solve > lv.comm_bytes_per_solve
    assert lv.n_supersteps == lv.n_levels


@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_multirhs_panel_matches_columnwise(problem, sched):
    """(n, R) panel through one compiled solve == R independent solves."""
    a, b, x_ref = problem
    rng = np.random.default_rng(7)
    B = np.column_stack([b, rng.uniform(-1, 1, (a.n, 2))])
    cfg = SolverConfig(block_size=16, sched=sched)
    solver = DistributedSolver(build_plan(a, 1, cfg), _mesh1())
    X = solver.solve(B)
    assert solver.n_solves == 1
    np.testing.assert_allclose(X[:, 0], x_ref, rtol=2e-4, atol=2e-4)
    for j in range(1, 3):
        np.testing.assert_allclose(X[:, j], reference_solve(a, B[:, j]),
                                   rtol=2e-4, atol=2e-4)


def test_transpose_solve_all_matrices(problem):
    a, b, _ = problem
    import scipy.sparse.linalg as spla

    from repro.sparse.matrix import to_scipy

    x = sptrsv(a, b, mesh=_mesh1(), config=SolverConfig(block_size=16), transpose=True)
    x_ref = spla.spsolve_triangular(to_scipy(a).T.tocsr(), b, lower=False)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4)


def test_pallas_backend_multirhs_end_to_end():
    """Whole multi-RHS solve with the Pallas trsm/gemm kernels (interpret)."""
    a = suite.random_levelled(120, 10, 3.0, seed=5)
    rng = np.random.default_rng(4)
    B = rng.uniform(-1, 1, (a.n, 3))
    cfg = SolverConfig(block_size=16, kernel_backend="pallas")
    solver = DistributedSolver(build_plan(a, 1, cfg), _mesh1())
    X = solver.solve(B)
    for j in range(3):
        np.testing.assert_allclose(X[:, j], reference_solve(a, B[:, j]),
                                   rtol=2e-4, atol=2e-4)
