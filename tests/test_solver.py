"""SpTRSV end-to-end vs scipy oracle — all scheduling/comm/partition modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import DistributedSolver, SolverConfig, build_plan, solve_local, sptrsv
from repro.core.blocking import pad_rhs, unpad_x
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


def _mesh1():
    return compat.make_mesh((1,), ("x",), devices=jax.devices()[:1])


MATRICES = {
    "levelled": lambda: suite.random_levelled(400, 24, 4.0, seed=3),
    "chain": lambda: suite.chain(150),
    "grid": lambda: suite.grid2d_factor(18, seed=1),
    "parallel": lambda: suite.block_diagonal_parallel(300, 12, 3.0, seed=2),
    "two_level": lambda: suite.random_levelled(300, 2, 8.0, seed=4),
}


@pytest.fixture(scope="module", params=list(MATRICES))
def problem(request):
    a = MATRICES[request.param]()
    b = np.random.default_rng(0).uniform(-1, 1, a.n)
    return a, b, reference_solve(a, b)


@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_all_modes_match_reference(problem, comm, sched):
    a, b, x_ref = problem
    cfg = SolverConfig(block_size=16, comm=comm, sched=sched)
    x = sptrsv(a, b, mesh=_mesh1(), config=cfg)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4)


def test_solve_local_matches_reference(problem):
    a, b, x_ref = problem
    plan = build_plan(a, 1, SolverConfig(block_size=8))
    xb = solve_local(plan, jnp.asarray(pad_rhs(b, plan.bs)))
    np.testing.assert_allclose(unpad_x(np.asarray(xb), plan.bs), x_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block_size", [4, 16, 64])
def test_block_size_invariance(block_size):
    a = MATRICES["levelled"]()
    b = np.random.default_rng(1).uniform(-1, 1, a.n)
    x = sptrsv(a, b, mesh=_mesh1(), config=SolverConfig(block_size=block_size))
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)


def test_solver_reuse_multiple_rhs():
    """Paper runs the solver 100x per matrix: plan/compile once, solve many."""
    a = MATRICES["grid"]()
    plan = build_plan(a, 1, SolverConfig(block_size=16))
    solver = DistributedSolver(plan, _mesh1())
    rng = np.random.default_rng(2)
    for _ in range(3):
        b = rng.uniform(-1, 1, a.n)
        np.testing.assert_allclose(solver.solve(b), reference_solve(a, b),
                                   rtol=2e-4, atol=2e-4)


def test_pallas_backend_end_to_end():
    """Whole solve with the Pallas kernels (interpret mode) instead of XLA ref."""
    a = suite.random_levelled(120, 10, 3.0, seed=5)
    b = np.random.default_rng(3).uniform(-1, 1, a.n)
    cfg = SolverConfig(block_size=16, kernel_backend="pallas")
    x = sptrsv(a, b, mesh=_mesh1(), config=cfg)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)


def test_comm_bytes_accounting():
    a = MATRICES["levelled"]()
    zc = build_plan(a, 4, SolverConfig(block_size=16, comm="zerocopy"))
    un = build_plan(a, 4, SolverConfig(block_size=16, comm="unified"))
    assert zc.comm_bytes_per_solve < un.comm_bytes_per_solve


def test_comm_bytes_syncfree_counts_counter_traffic():
    """Syncfree/unified psums in-degree counters on top of the accumulators —
    its predicted volume must exceed levelset/unified on the same matrix."""
    a = MATRICES["levelled"]()
    lv = build_plan(a, 4, SolverConfig(block_size=16, comm="unified", sched="levelset"))
    sf = build_plan(a, 4, SolverConfig(block_size=16, comm="unified", sched="syncfree"))
    assert sf.comm_bytes_per_solve > lv.comm_bytes_per_solve
    assert lv.n_supersteps == lv.n_levels


@pytest.mark.parametrize("sched", ["levelset", "syncfree"])
def test_multirhs_panel_matches_columnwise(problem, sched):
    """(n, R) panel through one compiled solve == R independent solves."""
    a, b, x_ref = problem
    rng = np.random.default_rng(7)
    B = np.column_stack([b, rng.uniform(-1, 1, (a.n, 2))])
    cfg = SolverConfig(block_size=16, sched=sched)
    solver = DistributedSolver(build_plan(a, 1, cfg), _mesh1())
    X = solver.solve(B)
    assert solver.n_solves == 1
    np.testing.assert_allclose(X[:, 0], x_ref, rtol=2e-4, atol=2e-4)
    for j in range(1, 3):
        np.testing.assert_allclose(X[:, j], reference_solve(a, B[:, j]),
                                   rtol=2e-4, atol=2e-4)


def test_transpose_solve_all_matrices(problem):
    a, b, _ = problem
    import scipy.sparse.linalg as spla

    from repro.sparse.matrix import to_scipy

    x = sptrsv(a, b, mesh=_mesh1(), config=SolverConfig(block_size=16), transpose=True)
    x_ref = spla.spsolve_triangular(to_scipy(a).T.tocsr(), b, lower=False)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4)


def test_pallas_backend_multirhs_end_to_end():
    """Whole multi-RHS solve with the Pallas trsm/gemm kernels (interpret)."""
    a = suite.random_levelled(120, 10, 3.0, seed=5)
    rng = np.random.default_rng(4)
    B = rng.uniform(-1, 1, (a.n, 3))
    cfg = SolverConfig(block_size=16, kernel_backend="pallas")
    solver = DistributedSolver(build_plan(a, 1, cfg), _mesh1())
    X = solver.solve(B)
    for j in range(3):
        np.testing.assert_allclose(X[:, j], reference_solve(a, B[:, j]),
                                   rtol=2e-4, atol=2e-4)
