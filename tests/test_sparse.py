import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property suite is optional (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.sparse import suite
from repro.sparse.matrix import (
    CSR, csc_to_csr, csr_to_csc, lower_triangular_from_coo, reference_solve, to_scipy,
)


def random_csr(n=64, avg=3.0, seed=0) -> CSR:
    rng = np.random.default_rng(seed)
    m = int(avg * n)
    return lower_triangular_from_coo(
        n, rng.integers(0, n, m), rng.integers(0, n, m), rng=rng
    )


def test_structure_invariants():
    a = random_csr(100, 4.0)
    assert a.row_ptr[0] == 0 and a.row_ptr[-1] == a.nnz
    # full diagonal, strictly lower otherwise
    for i in range(a.n):
        cols = a.col_idx[a.row_ptr[i]:a.row_ptr[i + 1]]
        assert cols[-1] == i  # diagonal last
        assert np.all(cols[:-1] < i)
        assert np.all(np.diff(cols) > 0)


def test_csc_csr_roundtrip():
    a = random_csr(80, 5.0, seed=3)
    csc = csr_to_csc(a)
    csc.validate()
    b = csc_to_csr(csc)
    assert np.array_equal(a.row_ptr, b.row_ptr)
    assert np.array_equal(a.col_idx, b.col_idx)
    np.testing.assert_allclose(a.val, b.val)


def test_reference_solve_is_triangular_solution():
    a = random_csr(60, 4.0, seed=1)
    b = np.random.default_rng(0).uniform(-1, 1, a.n)
    x = reference_solve(a, b)
    np.testing.assert_allclose(to_scipy(a) @ x, b, rtol=1e-9, atol=1e-9)


@given(st.integers(16, 96), st.integers(1, 12), st.floats(1.5, 6.0))
@settings(max_examples=20, deadline=None)
def test_random_levelled_hits_level_target(n, levels, avg):
    from repro.core.analysis import level_sets

    a = suite.random_levelled(n, levels, avg, seed=7)
    sched = level_sets(a)
    assert sched.n_levels == min(levels, n)


def test_suite_signatures():
    for e in suite.table1_suite(scale=0.05):
        a = e.build()
        assert a.n >= 64
        assert a.nnz >= a.n  # diagonal present
