"""SSM invariants: chunked scan == one-chunk scan; decode == prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.ssm import init_mamba1, init_mamba2, mamba1, mamba2

KEY = jax.random.PRNGKey(0)


def _cfg(arch, chunk):
    return dataclasses.replace(
        get_reduced(arch), dtype="float32", param_dtype="float32", ssm_chunk=chunk
    )


@pytest.mark.parametrize("arch,init,fn", [
    ("falcon-mamba-7b", init_mamba1, mamba1),
    ("zamba2-7b", init_mamba2, mamba2),
])
def test_chunked_equals_monolithic(arch, init, fn):
    B, S = 2, 64
    cfg_small = _cfg(arch, 8)
    cfg_full = _cfg(arch, 64)
    p = init(KEY, cfg_full, jnp.float32)
    u = jax.random.normal(KEY, (B, S, cfg_full.d_model))
    y_full, _ = fn(p, u, cfg_full)
    y_chunk, _ = fn(p, u, cfg_small)
    np.testing.assert_allclose(y_chunk, y_full, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch,init,fn", [
    ("falcon-mamba-7b", init_mamba1, mamba1),
    ("zamba2-7b", init_mamba2, mamba2),
])
def test_decode_state_equals_prefill(arch, init, fn):
    from repro.models.model import _block_cache

    B, S = 2, 32
    cfg = _cfg(arch, 8)
    kind = "M" if arch.startswith("falcon") else "S"
    p = init(KEY, cfg, jnp.float32)
    u = jax.random.normal(KEY, (B, S, cfg.d_model))
    y_full, _ = fn(p, u, cfg)
    cache = jax.tree.map(
        lambda x: x.astype(jnp.float32), _block_cache(kind, cfg, B, S, jnp.float32)
    )
    cache = {k: v for k, v in cache.items() if k in ("conv", "conv_bc", "h")}
    ys = []
    for t in range(S):
        y, cache = fn(p, u[:, t:t + 1], cfg, cache)
        ys.append(y[:, 0])
    np.testing.assert_allclose(jnp.stack(ys, 1), y_full, rtol=5e-4, atol=5e-4)


def test_mamba2_state_decay_bounds():
    """Hypothesis-style invariant: with dt>=0 the decay factor is in (0,1]."""
    cfg = _cfg("zamba2-7b", 8)
    p = init_mamba2(KEY, cfg, jnp.float32)
    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(jax.random.normal(KEY, (100,)) + p["dt_bias"][0])
    decay = jnp.exp(dt * A[0])
    assert bool(jnp.all(decay > 0)) and bool(jnp.all(decay <= 1.0))
