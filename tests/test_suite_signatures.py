"""Every Table-I suite entry must land in its declared structural regime.

The suite generators are synthetic stand-ins for the paper's matrices; what
they must preserve is the (levels, parallelism) *regime* that drives SpTRSV
behaviour, not the exact counts. One classification rule is applied to both
the declared paper signature and the measured signature at default scale:

* embarrassingly-parallel — few wavefronts (levels <= 40)
* chain-dominated         — parallelism below levels/5 (long critical path)
* balanced                — everything else
"""
import numpy as np
import pytest

from repro.core.analysis import level_sets, metrics
from repro.sparse.suite import table1_suite


def _regime(levels: float, parallelism: float) -> str:
    if levels <= 40:
        return "embarrassingly-parallel"
    if parallelism < levels / 5:
        return "chain-dominated"
    return "balanced"


@pytest.mark.parametrize("entry", table1_suite(), ids=lambda e: e.name)
def test_entry_lands_in_declared_regime(entry):
    a = entry.build()
    m = metrics(a, level_sets(a))
    declared = _regime(entry.paper_levels, entry.paper_parallelism)
    measured = _regime(m.n_levels, m.parallelism)
    assert measured == declared, (
        f"{entry.name}: declared {declared} "
        f"(paper levels={entry.paper_levels}, par={entry.paper_parallelism}) but "
        f"measured {measured} (levels={m.n_levels}, par={m.parallelism:.1f})"
    )


def test_suite_covers_all_three_regimes():
    regimes = {_regime(e.paper_levels, e.paper_parallelism) for e in table1_suite()}
    assert regimes == {"embarrassingly-parallel", "chain-dominated", "balanced"}


def test_signatures_are_deterministic():
    """Generators are seeded: the structural signature must not drift."""
    for entry in table1_suite(0.05):
        a1, a2 = entry.build(), entry.build()
        assert a1.nnz == a2.nnz
        np.testing.assert_array_equal(a1.col_idx, a2.col_idx)
