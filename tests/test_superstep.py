"""Fused Pallas superstep megakernel vs the lax.switch compacted executor.

Bit-exactness strategy: XLA does not promise a reduction order across two
separately-compiled programs, so float comparisons between executors are only
meaningful when the arithmetic is *exact* — see the dyadic contract in
``tests/strategies.py`` (the shared home of these generators).
``assert_array_equal`` then really is bit-exactness. Real-valued suites ride
along with the scipy oracle at the usual tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from strategies import EXACT_MATRICES, dyadic_rhs, mesh1 as _mesh1
from repro.core import (
    DistributedSolver, SolverConfig, build_plan, dispatch_stats,
    fused_segments, solve_local, sptrsv,
)
from repro.core.blocking import pad_rhs
from repro.core.solver import _frontier_ladder, level_widths
from repro.kernels import ops
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


@pytest.fixture(scope="module", params=list(EXACT_MATRICES))
def exact_problem(request):
    a = EXACT_MATRICES[request.param]()
    b = dyadic_rhs(a.n)
    x_ref = reference_solve(a, b)
    return a, b, x_ref


def test_dyadic_matrices_are_exact():
    for name, make in EXACT_MATRICES.items():
        a = make()
        assert strategies.exactness_holds(a, dyadic_rhs(a.n)), name


# ---------------------------------------------------------------------------
# fused levelset megakernel vs the lax.switch executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_size", [8, 16])
def test_fused_bit_exact_vs_switch(exact_problem, block_size):
    a, b, x_ref = exact_problem
    mesh = _mesh1()
    sw = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=block_size, kernel_backend="pallas")), mesh)
    fu = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=block_size, kernel_backend="fused")), mesh)
    xs, xf = sw.solve(b), fu.solve(b)
    np.testing.assert_array_equal(xs, xf)
    np.testing.assert_allclose(xf, x_ref, rtol=0, atol=0)


def test_fused_multirhs_bit_exact(exact_problem):
    """(n, R) panels through the split trsm/gemm kernel arithmetic."""
    a, b, _ = exact_problem
    rng = np.random.default_rng(2)
    B = np.column_stack([b, rng.integers(-3, 4, (a.n, 2))]).astype(np.float32)
    mesh = _mesh1()
    sw = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=16, kernel_backend="pallas")), mesh)
    fu = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=16, kernel_backend="fused")), mesh)
    Xs, Xf = sw.solve(B), fu.solve(B)
    assert sw.n_solves == fu.n_solves == 1
    np.testing.assert_array_equal(Xs, Xf)


def test_solve_local_fused_bit_exact(exact_problem):
    a, b, _ = exact_problem
    plan_sw = build_plan(a, 1, SolverConfig(block_size=8, kernel_backend="pallas"))
    plan_f = build_plan(a, 1, SolverConfig(block_size=8, kernel_backend="fused"))
    bp = jnp.asarray(pad_rhs(b, plan_sw.bs))
    np.testing.assert_array_equal(
        np.asarray(solve_local(plan_sw, bp)), np.asarray(solve_local(plan_f, bp)))


def test_fused_transpose_solve(exact_problem):
    a, b, _ = exact_problem
    mesh = _mesh1()
    xs = sptrsv(a, b, mesh=mesh, transpose=True,
                config=SolverConfig(block_size=16, kernel_backend="pallas"))
    xf = sptrsv(a, b, mesh=mesh, transpose=True,
                config=SolverConfig(block_size=16, kernel_backend="fused"))
    np.testing.assert_array_equal(xs, xf)


def test_fused_real_values_match_oracle():
    """Real-valued skewed + banded suites: fused agrees with the scipy oracle
    and with the switch executor at float tolerance (XLA fusion may differ by
    ulps across separately-compiled programs)."""
    mats = {
        "skewed": suite.random_levelled(400, 24, 4.0, seed=6),
        "banded": suite.random_levelled(300, 24, 4.0, seed=7, locality=0.8),
    }
    mesh = _mesh1()
    for name, a in mats.items():
        b = np.random.default_rng(3).uniform(-1, 1, a.n)
        x_ref = reference_solve(a, b)
        for sched in ("levelset", "syncfree"):
            cfg = SolverConfig(block_size=16, sched=sched, kernel_backend="fused")
            x = sptrsv(a, b, mesh=mesh, config=cfg)
            np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4,
                                       err_msg=f"{name}/{sched}")


# ---------------------------------------------------------------------------
# streaming HBM tile store (kernel_backend="fused_streamed")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_size", [8, 16])
def test_streamed_bit_exact_vs_resident_and_switch(exact_problem, block_size):
    """The streaming store changes data *movement* only: streamed, resident
    fused, and the lax.switch executor agree bit-for-bit on the dyadic
    exact-arithmetic suites."""
    a, b, x_ref = exact_problem
    mesh = _mesh1()
    xs = {}
    for kb in ("pallas", "fused", "fused_streamed"):
        xs[kb] = DistributedSolver(build_plan(
            a, 1, SolverConfig(block_size=block_size, kernel_backend=kb)),
            mesh).solve(b)
    np.testing.assert_array_equal(xs["pallas"], xs["fused"])
    np.testing.assert_array_equal(xs["fused"], xs["fused_streamed"])
    np.testing.assert_allclose(xs["fused_streamed"], x_ref, rtol=0, atol=0)


def test_streamed_multirhs_bit_exact(exact_problem):
    """(n, R) panels stream the same tile slices once per solve, whatever R."""
    a, b, _ = exact_problem
    rng = np.random.default_rng(2)
    B = np.column_stack([b, rng.integers(-3, 4, (a.n, 2))]).astype(np.float32)
    mesh = _mesh1()
    fu = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=16, kernel_backend="fused")), mesh)
    st = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=16, kernel_backend="fused_streamed")), mesh)
    np.testing.assert_array_equal(fu.solve(B), st.solve(B))


def test_streamed_transpose_solve(exact_problem):
    a, b, _ = exact_problem
    mesh = _mesh1()
    xf = sptrsv(a, b, mesh=mesh, transpose=True,
                config=SolverConfig(block_size=16, kernel_backend="fused"))
    xs = sptrsv(a, b, mesh=mesh, transpose=True,
                config=SolverConfig(block_size=16, kernel_backend="fused_streamed"))
    np.testing.assert_array_equal(xf, xs)


def test_streamed_vmem_buffers_sized_by_max_level_slice():
    """Acceptance (trace-time): the streamed kernel's VMEM scratch is two
    double-buffers sized by the *max per-level bucket width* — never by the
    total tile/diag store. Recorded by superstep.LAST_STREAM_ALLOC when the
    streamed launch traces."""
    from repro.kernels import superstep
    from repro.core.solver import level_widths as _lw, streamed_stores

    a = suite.random_levelled(600, 30, 3.0, seed=8)
    b = np.random.default_rng(5).uniform(-1, 1, a.n)
    plan = build_plan(a, 1, SolverConfig(block_size=8,
                                         kernel_backend="fused_streamed"))
    wid = _lw(plan)
    WS, WU = int(wid[:, 0].max()), int(wid[:, 1].max())
    total_tiles = plan.tiles.shape[1]
    assert WU < total_tiles / 4, (WU, total_tiles)  # premise: many levels

    superstep.LAST_STREAM_ALLOC.clear()
    x = DistributedSolver(plan, _mesh1()).solve(b)
    np.testing.assert_allclose(x, reference_solve(a, b), rtol=2e-4, atol=2e-4)
    alloc = superstep.LAST_STREAM_ALLOC
    assert alloc, "streamed launch must record its trace-time scratch shapes"
    B = plan.bs.B
    assert alloc["diag_buf"] == (2, WS, B, B)
    assert alloc["tile_buf"] == (2, WU, B, B)
    # the HBM stores carry the whole schedule; VMEM only the widest slice x2
    diag_s, tiles_s = streamed_stores(plan)
    assert alloc["diag_store"] == diag_s.shape[1:]
    assert alloc["tile_store"] == tiles_s.shape[1:]
    assert 2 * WU < tiles_s.shape[1]


def test_fused_auto_streams_above_vmem_limit(monkeypatch, exact_problem):
    """Plain kernel_backend="fused" upgrades to the streaming store when the
    resident footprint exceeds REPRO_STREAM_VMEM_LIMIT — and still matches
    the switch executor bit-for-bit."""
    from repro.core.solver import fused_streaming, fused_vmem_bytes

    a, b, _ = exact_problem
    cfg = SolverConfig(block_size=16, kernel_backend="fused")
    plan = build_plan(a, 1, cfg)

    monkeypatch.setenv("REPRO_STREAM_VMEM_LIMIT", str(2**40))
    assert not fused_streaming(plan)
    assert not dispatch_stats(plan)["streamed"]

    monkeypatch.setenv("REPRO_STREAM_VMEM_LIMIT", "1")
    assert fused_streaming(plan)
    ds = dispatch_stats(plan)
    assert ds["streamed"] and ds["stream_dma_bytes"] > 0
    # the reported footprint is the streamed one: bounded by the widest level
    # slice, strictly below the resident store it replaced
    assert ds["fused_vmem_bytes"] == fused_vmem_bytes(plan, streamed=True)
    assert ds["fused_vmem_bytes"] < fused_vmem_bytes(plan, streamed=False)

    xs = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=16, kernel_backend="pallas")),
        _mesh1()).solve(b)
    xa = DistributedSolver(plan, _mesh1()).solve(b)
    np.testing.assert_array_equal(xs, xa)


def test_streamed_vmem_footprint_bounded_by_widest_slice(monkeypatch):
    """Acceptance: on a matrix whose total tile store exceeds the resident
    threshold, the streamed footprint is bounded by the widest level slice
    (double-buffered) plus the O(n·B) vectors, not by the tile count."""
    from repro.core.solver import (fused_streaming, fused_vmem_bytes,
                                   level_widths as _lw)

    a = suite.random_levelled(600, 30, 3.0, seed=8)
    plan = build_plan(a, 1, SolverConfig(block_size=8, kernel_backend="fused"))
    resident = fused_vmem_bytes(plan, streamed=False)
    monkeypatch.setenv("REPRO_STREAM_VMEM_LIMIT", str(resident - 1))
    assert fused_streaming(plan)  # total store exceeds the threshold
    streamed = fused_vmem_bytes(plan, streamed=True)
    wid = _lw(plan)
    B = plan.bs.B
    widest_slice = 2 * (int(wid[:, 0].max()) + int(wid[:, 1].max())) * B * B * 4
    vectors = resident - (plan.diag.shape[0] + plan.tiles.shape[1]) * B * B * 4
    assert streamed == widest_slice + vectors
    assert streamed < resident


def test_streamed_refresh_rearms_hbm_stores(exact_problem):
    """Numeric refresh must reach the schedule-ordered HBM stores: after
    DistributedSolver.refresh the streamed executor solves with the NEW
    values, bit-identically to a fresh build on them."""
    from repro.core import refresh_plan
    from repro.sparse.matrix import CSR

    a, b, _ = exact_problem
    a2 = CSR(n=a.n, row_ptr=a.row_ptr, col_idx=a.col_idx, val=a.val * 0.5)
    mesh = _mesh1()
    cfg = SolverConfig(block_size=16, kernel_backend="fused_streamed")
    solver = DistributedSolver(build_plan(a, 1, cfg), mesh)
    solver.solve(b)  # compile on a's values
    solver.refresh(refresh_plan(solver.plan, a2))
    fresh = DistributedSolver(build_plan(a2, 1, cfg), mesh)
    np.testing.assert_array_equal(solver.solve(b), fresh.solve(b))


# ---------------------------------------------------------------------------
# frontier-bucketed syncfree vs the dense scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
def test_frontier_syncfree_agrees_with_dense(exact_problem, comm):
    a, b, x_ref = exact_problem
    mesh = _mesh1()
    dense = SolverConfig(block_size=16, sched="syncfree", comm=comm)
    front = SolverConfig(block_size=16, sched="syncfree", comm=comm,
                         kernel_backend="fused")
    xd = sptrsv(a, b, mesh=mesh, config=dense)
    xf = sptrsv(a, b, mesh=mesh, config=front)
    np.testing.assert_array_equal(xd, xf)
    np.testing.assert_allclose(xf, x_ref, rtol=0, atol=0)


def test_frontier_syncfree_multirhs(exact_problem):
    a, b, _ = exact_problem
    rng = np.random.default_rng(4)
    B = np.column_stack([b, rng.integers(-3, 4, (a.n, 2))]).astype(np.float32)
    mesh = _mesh1()
    dense = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=16, sched="syncfree")), mesh)
    front = DistributedSolver(build_plan(
        a, 1, SolverConfig(block_size=16, sched="syncfree",
                           kernel_backend="fused")), mesh)
    np.testing.assert_array_equal(dense.solve(B), front.solve(B))


def test_frontier_work_scales_with_bucket_width(monkeypatch):
    """Acceptance: syncfree per-superstep work scales with the frontier
    bucket, not the device's total local rows. Recorded at trace time: the
    dense executor's TRSV batches span all MLR local rows, the frontier
    executor's largest branch stops at the ladder cap derived from the widest
    block level — far below MLR on a chain-skewed matrix."""
    a = suite.random_levelled(600, 30, 3.0, seed=8)
    b = np.random.default_rng(5).uniform(-1, 1, a.n)
    recorded = []
    orig = ops.batched_block_trsv

    def spy(diag, rhs, **kw):
        recorded.append(int(diag.shape[0]))
        return orig(diag, rhs, **kw)

    monkeypatch.setattr(ops, "batched_block_trsv", spy)
    mesh = _mesh1()

    cfg_f = SolverConfig(block_size=8, sched="syncfree", kernel_backend="fused")
    plan = build_plan(a, 1, cfg_f)
    MLR = plan.local_rows.shape[1]
    cap = plan.frontier_caps[0]
    assert cap < MLR / 4, (cap, MLR)  # premise: skewed levels << local rows

    sptrsv(a, b, mesh=mesh, config=cfg_f)
    frontier_widths = set(recorded)
    recorded.clear()
    sptrsv(a, b, mesh=mesh, config=SolverConfig(block_size=8, sched="syncfree"))
    dense_widths = set(recorded)

    ladder = set(_frontier_ladder(min(cap, MLR)))
    assert frontier_widths == ladder
    assert max(frontier_widths) <= max(ladder) < MLR
    assert MLR in dense_widths  # the dense scan really pays all local rows


# ---------------------------------------------------------------------------
# plan-level structure: segments, dispatch counts, ladders
# ---------------------------------------------------------------------------


def test_fused_segments_partition_levels(exact_problem):
    a, _, _ = exact_problem
    for comm, D in (("zerocopy", 1), ("zerocopy", 4), ("unified", 4)):
        plan = build_plan(a, D, SolverConfig(block_size=16, comm=comm))
        segs = fused_segments(plan)
        # segments tile [0, T) exactly, in order
        assert segs[0, 0] == 0 and segs[-1, 1] == plan.n_levels
        np.testing.assert_array_equal(segs[1:, 0], segs[:-1, 1])
        if comm == "unified" and D > 1:
            assert len(segs) == plan.n_levels  # dense psum every superstep
        if D == 1:
            assert len(segs) == 1  # whole solve in one launch
        wid = level_widths(plan)
        if comm == "zerocopy" and D > 1 and plan.n_boundary_rows > 0:
            # every segment break sits exactly before an exchange level
            for lo in segs[1:, 0]:
                assert wid[lo, 2] > 0


def test_dispatch_stats_fused_wins(exact_problem):
    a, _, _ = exact_problem
    for D in (1, 4):
        plan = build_plan(a, D, SolverConfig(block_size=16))
        ds = dispatch_stats(plan)
        assert ds["fused_launches"] == len(fused_segments(plan))
        assert ds["fused_launches"] < ds["switch_dispatches"]


def test_frontier_ladder_properties():
    for cap in (1, 2, 5, 37, 1000, 123456):
        lad = _frontier_ladder(cap)
        assert lad[0] >= 1 and lad[-1] == cap
        assert list(lad) == sorted(set(lad))
        assert len(lad) <= 12
    assert _frontier_ladder(8) == (1, 2, 4, 8)


def test_fused_backend_env_selection(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "fused")
    assert ops.executor_backend(None) == "fused"
    assert ops.op_backend(None) in ("reference", "pallas")
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "bogus")
    with pytest.raises(ValueError):
        ops.executor_backend(None)


def test_fused_unified_multidevice_plan_builds():
    """Unified fused executor compiles per-level segments with the split-delta
    carry; structure-only check here (execution is covered on 8 devices in
    test_multidevice)."""
    a = EXACT_MATRICES["skewed"]()
    plan = build_plan(a, 4, SolverConfig(block_size=16, comm="unified",
                                         kernel_backend="fused"))
    segs = fused_segments(plan)
    assert len(segs) == plan.n_levels
    assert dispatch_stats(plan)["exchanges"] == plan.n_levels
