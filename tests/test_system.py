"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import pytest

from repro import compat
from repro.core import SolverConfig, sptrsv
from repro.sparse import suite
from repro.sparse.matrix import reference_solve


def _mesh1():
    import jax

    return compat.make_mesh((1,), ("x",))


def test_paper_pipeline_analyse_plan_solve():
    """The paper's workflow: load CSC -> in-degree analysis -> solve 100x."""
    from repro.core import DistributedSolver, build_plan
    from repro.sparse.matrix import csr_to_csc, csc_to_csr

    a = suite.random_levelled(500, 16, 3.5, seed=9)
    csc = csr_to_csc(a)  # the paper's input format
    csc.validate()
    a2 = csc_to_csr(csc)
    plan = build_plan(a2, 1, SolverConfig(block_size=16))
    solver = DistributedSolver(plan, _mesh1())
    rng = np.random.default_rng(0)
    for _ in range(3):
        b = rng.uniform(-1, 1, a.n)
        np.testing.assert_allclose(
            solver.solve(b), reference_solve(a, b), rtol=2e-4, atol=2e-4
        )


def test_table1_suite_solves_end_to_end():
    """Every Table-I matrix class solves correctly under the zero-copy config."""
    rng = np.random.default_rng(1)
    for entry in suite.table1_suite(scale=0.02):
        a = entry.build()
        b = rng.uniform(-1, 1, a.n)
        x = sptrsv(a, b, mesh=_mesh1(),
                   config=SolverConfig(block_size=16, partition="taskpool"))
        err = np.abs(x - reference_solve(a, b)).max() / max(1e-9, np.abs(x).max())
        assert err < 1e-4, (entry.name, err)


def test_end_to_end_training_loss_decreases():
    """~100M-class example config, a handful of steps, loss must trend down
    on a learnable synthetic task (repeated batch)."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.model import loss_fn
    from repro.train.optim import adamw_init, adamw_update

    cfg = dataclasses.replace(get_reduced("llama3.2-1b"), dtype="float32",
                              param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (4, 33))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch["tokens"], batch["labels"], remat=False)
        )(params)
        params, opt, _ = adamw_update(params, g, opt, lr=3e-3, weight_decay=0.0)
        return params, opt, loss

    losses = []
    for _ in range(12):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses  # memorizes the repeated batch


def test_serve_roundtrip():
    from repro.launch.serve import run as serve_run

    toks = serve_run("llama3.2-1b", batch=2, prompt_len=8, new_tokens=4, quiet=True)
    assert toks.shape == (2, 4)
    assert int(toks.max()) < 256  # reduced vocab


def test_dryrun_cell_applicability_count():
    from repro.configs import ARCH_IDS, SHAPES, cell_applicable

    total = len(ARCH_IDS) * len(SHAPES)
    runnable = sum(cell_applicable(a, s)[0] for a in ARCH_IDS for s in SHAPES)
    assert total == 40
    assert runnable == 32  # 30 non-long cells + zamba2/falcon long_500k
