"""Fault tolerance: checkpoint/resume determinism, atomic commit, elasticity."""
import os

import numpy as np
import pytest

from repro import compat
from repro.checkpoint import CheckpointManager
from repro.launch.train import run


def test_resume_is_bitwise_deterministic(tmp_path):
    """Crash after step 9 + resume == uninterrupted run (same data, same loss)."""
    d1 = str(tmp_path / "a")
    full = run("llama3.2-1b", steps=14, ckpt_dir=d1, ckpt_every=5,
               global_batch=2, seq_len=16, quiet=True)
    d2 = str(tmp_path / "b")
    run("llama3.2-1b", steps=10, ckpt_dir=d2, ckpt_every=5,
        global_batch=2, seq_len=16, quiet=True)  # "crashes" after step 9
    resumed = run("llama3.2-1b", steps=14, ckpt_dir=d2, ckpt_every=5,
                  global_batch=2, seq_len=16, quiet=True)  # picks up at 10
    np.testing.assert_allclose(resumed, full[10:], rtol=1e-5)


def test_checkpoint_atomic_commit(tmp_path):
    import jax.numpy as jnp

    mgr = CheckpointManager(str(tmp_path))
    params = {"w": jnp.ones((4, 4))}
    opt = {"m": {"w": jnp.zeros((4, 4))}, "v": {"w": jnp.zeros((4, 4))},
           "step": jnp.zeros((), jnp.int32)}
    mgr.save(3, params, opt, {"arch": "t"})
    assert mgr.latest_step() == 3
    # a stale .tmp dir must never be visible as a committed step
    os.makedirs(str(tmp_path / "step_000000007.tmp"))
    assert mgr.latest_step() == 3
    p2, o2, man = mgr.restore(3, params, opt)
    np.testing.assert_array_equal(p2["w"], params["w"])
    assert man["arch"] == "t"


def test_checkpoint_gc_keeps_latest(tmp_path):
    import jax.numpy as jnp

    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"w": jnp.ones(2)}
    opt = {"m": {"w": jnp.zeros(2)}, "v": {"w": jnp.zeros(2)},
           "step": jnp.zeros((), jnp.int32)}
    for s in (1, 2, 3, 4):
        mgr.save(s, params, opt)
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(kept) == 2 and kept[-1].endswith("4")


def test_elastic_restore_different_topology(tmp_path):
    """Checkpoints are global arrays: restoring re-shards to the current mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    params = {"w": jnp.arange(16.0).reshape(4, 4)}
    opt = {"m": {"w": jnp.zeros((4, 4))}, "v": {"w": jnp.zeros((4, 4))},
           "step": jnp.zeros((), jnp.int32)}
    mgr.save(0, params, opt, {"mesh": [1]})
    mesh = compat.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    osh = jax.tree.map(lambda _: NamedSharding(mesh, P()), opt)
    p2, _, _ = mgr.restore(0, params, opt, shardings=(sh, osh))
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
