"""Static plan verifier (repro.verify): mutation fixtures + wiring tests.

The mutation tests are the verifier's own test oracle (ISSUE 7 satellite):
each takes a plan that verifies clean, corrupts exactly one schedule facet
via ``dataclasses.replace`` — swap two levels, drop an exchange row, shrink a
bucket width, overlap two DMA slices, double-assign a row, and friends — and
asserts the verifier flags it with the *exact* rule id at the right location.
Every fixture first asserts the uncorrupted plan passes, so a verifier that
rubber-stamps everything (or rejects everything) fails loudly here.

The empty-cut regression tests pin the real invariant violation the verifier
surfaced (``hb.exchange.degenerate``): unified/multi-device plans over an
empty dependency cut used to schedule dense psums and per-level fused
segmentation although every update is device-local.
"""
import dataclasses
import io
from unittest import mock

import numpy as np
import pytest

import strategies
from repro.core import DistributedSolver, SolverConfig, build_plan, dispatch_stats
from repro.core.solver import fused_segments
from repro.sparse import suite
from repro.verify import (PlanVerificationError, VerificationReport,
                          env_verify_level, verify_plan)

# -----------------------------------------------------------------------
# fixtures: plans that verify clean at the strictest level
# -----------------------------------------------------------------------


@pytest.fixture(scope="module")
def chain_plan():
    """Single-device chain: one row and one tile per level, no bucket slack —
    the sharpest fixture for ordering mutations (every slice is tight)."""
    return build_plan(suite.chain(40), 1, SolverConfig(block_size=8))


@pytest.fixture(scope="module")
def multi_plan():
    """Two-device levelset/zerocopy plan with a real cut: exchanges, bucket
    slack (pad slots inside slices), multiple buckets."""
    a = suite.random_levelled(400, 8, 4.0, seed=6)
    return build_plan(a, 2, SolverConfig(block_size=8, partition="taskpool"))


@pytest.fixture(scope="module")
def syncfree_plan():
    a = suite.random_levelled(400, 8, 4.0, seed=6)
    return build_plan(a, 2, SolverConfig(block_size=8, sched="syncfree",
                                         partition="taskpool"))


@pytest.fixture(scope="module")
def dagpart_plan():
    """Two-device merged-superstep plan with a real cut and a non-trivial
    ``step_off`` (some levels merge, some stay boundaries)."""
    a = suite.random_levelled(400, 8, 4.0, seed=6)
    return build_plan(a, 2, SolverConfig(block_size=8, sched="dagpart",
                                         partition="taskpool"))


def clean(plan):
    """Assert the uncorrupted plan verifies clean, so a mutation test can
    never pass because the verifier rejects (or ignores) everything."""
    report = verify_plan(plan, level="strict")
    assert report.passed, report.summary() + "\n" + "\n".join(
        str(f) for f in report.findings)
    return plan


def mutate(plan, **fields):
    return dataclasses.replace(plan, **fields)


def rules_of(report):
    return {f.rule for f in report.findings}


def level_slice(plan, t, col):
    lo = int(plan.lvl_off[t, col])
    return lo, lo + int(plan.buckets[int(plan.lvl_bucket[t])][col])


# -----------------------------------------------------------------------
# mutation fixtures (ISSUE 7 satellite): one corruption, one exact rule
# -----------------------------------------------------------------------


def test_mutation_swap_two_levels(chain_plan):
    """Swapping two solve slices breaks src-before: the level-1 tile now
    reads a source row that only solves in superstep 2."""
    plan = clean(chain_plan)
    sr = plan.solve_rows.copy()
    (l1, _), (l2, _) = level_slice(plan, 1, 0), level_slice(plan, 2, 0)
    sr[:, [l1, l2]] = sr[:, [l2, l1]]
    report = verify_plan(mutate(plan, solve_rows=sr), level="basic")
    assert not report.passed
    bad = report.by_rule("hb.upd.src-before")
    assert bad and bad[0].level == 1
    # the swapped-down row's own update now lands in its solve superstep
    assert report.by_rule("hb.upd.dest-after")


def test_mutation_drop_exchange_row(multi_plan):
    """Padding out one exchange entry leaves a remote-dependent row reading
    only its local partial sum."""
    plan = clean(multi_plan)
    owner = np.asarray(plan.part.owner)
    rows, cols = plan.bs.off_rows, plan.bs.off_cols
    remote_dest = set(np.unique(rows[owner[cols] != owner[rows]]).tolist())
    assert remote_dest, "fixture must have a non-empty cut"
    idx = next(i for i, r in enumerate(plan.ex_rows)
               if int(r) in remote_dest)
    victim = int(plan.ex_rows[idx])
    ex = plan.ex_rows.copy()
    ex[idx] = plan.bs.nb  # pad sentinel: psum of the inert slot
    report = verify_plan(mutate(plan, ex_rows=ex), level="basic")
    bad = report.by_rule("hb.exchange.missing")
    assert bad and victim in bad[0].rows


def test_mutation_shrink_bucket_width(chain_plan):
    """Shrinking a bucket's solve width truncates every level using it."""
    plan = clean(chain_plan)
    bid = int(plan.lvl_bucket[0])
    ws, wu, we = plan.buckets[bid]
    assert ws >= 1
    buckets = tuple((ws - 1, wu, we) if i == bid else b
                    for i, b in enumerate(plan.buckets))
    report = verify_plan(mutate(plan, buckets=buckets), level="contracts")
    bad = report.by_rule("kc.buckets.cover")
    assert bad and bad[0].level == 0
    # the offset table no longer cumsums the (shrunken) widths either
    assert report.by_rule("kc.offsets.cumsum")
    assert report.by_rule("kc.flats.length")


def test_mutation_overlap_dma_slices(chain_plan):
    """Shifting one level's update offset overlaps the previous level's HBM
    slice — the streamed kernel would DMA level 0's tile into level 1's
    compute — and leaves this level's own last slot uncovered."""
    plan = clean(chain_plan)
    off = plan.lvl_off.copy()
    assert off[1, 1] > 0
    off[1, 1] -= 1
    report = verify_plan(mutate(plan, lvl_off=off), level="contracts")
    msgs = [f.message for f in report.by_rule("kc.stream.slices")]
    assert any("more than one level slice" in m for m in msgs)
    assert any("covered by no level slice" in m for m in msgs)
    assert report.by_rule("kc.offsets.cumsum")


def test_mutation_double_assign_row(multi_plan):
    """Writing an already-solved row into a pad slot of a later slice solves
    it twice — the second TRSV runs on a stale accumulator."""
    plan = clean(multi_plan)
    sr = plan.solve_rows.copy()
    spot = None
    for t in range(1, plan.n_levels):
        lo, hi = level_slice(plan, t, 0)
        for d in range(plan.n_devices):
            pads = np.nonzero(sr[d, lo:hi] == -1)[0]
            if not pads.size:
                continue  # no bucket slack for this device at this level
            for te in range(t):  # a row d already solved earlier
                le, he = level_slice(plan, te, 0)
                real = [int(r) for r in sr[d, le:he] if int(r) != -1]
                if real:
                    spot = (d, lo + int(pads[0]), t, te, real[0])
                    break
            if spot:
                break
        if spot:
            break
    assert spot, "fixture must have bucket slack"
    d, slot, t, te, victim = spot
    sr[d, slot] = victim
    report = verify_plan(mutate(plan, solve_rows=sr), level="basic")
    bad = report.by_rule("hb.solve.once")
    assert bad and victim in bad[0].rows
    assert f"supersteps [{te}, {t}]" in bad[0].message


def test_mutation_double_schedule_tile(chain_plan):
    """Re-scheduling a store slot double-counts its contribution."""
    plan = clean(chain_plan)
    ut = plan.upd_tiles.copy()
    (l0, _), (l1, _) = level_slice(plan, 0, 1), level_slice(plan, 1, 1)
    ut[0, l1] = ut[0, l0]
    report = verify_plan(mutate(plan, upd_tiles=ut), level="basic")
    bad = report.by_rule("hb.upd.once")
    assert bad and any("updated twice" in f.message for f in bad)
    # the displaced level-1 tile is now never scheduled
    assert any("never scheduled" in f.message for f in bad)


def test_mutation_disowned_row(multi_plan):
    """A row scheduled on a device that does not own it solves against a
    store that never receives the row's tiles."""
    plan = clean(multi_plan)
    sr = plan.solve_rows.copy()
    lo, hi = level_slice(plan, 0, 0)
    d = next(d for d in range(plan.n_devices)
             if any(int(r) != -1 for r in sr[d, lo:hi]))
    other = (d + 1) % plan.n_devices
    pos = lo + next(i for i, r in enumerate(sr[d, lo:hi]) if int(r) != -1)
    row = int(sr[d, pos])
    sr[other, pos], sr[d, pos] = row, -1
    report = verify_plan(mutate(plan, solve_rows=sr), level="basic")
    bad = report.by_rule("hb.solve.owner")
    assert bad and bad[0].device == other and row in bad[0].rows


def test_mutation_undershoot_frontier_caps(syncfree_plan):
    """A frontier cap below the widest per-device level silently drops
    solves: the runtime marks all ready rows solved but only computes the
    dispatched branch width."""
    plan = clean(syncfree_plan)
    report = verify_plan(mutate(plan, frontier_caps=(1, 1)), level="basic")
    bad = report.by_rule("hb.syncfree.caps")
    assert len(bad) == 2  # both the solve and the update cap undershoot


def test_mutation_duplicate_boundary_row(syncfree_plan):
    """A boundary row listed twice is scatter-added twice per sweep."""
    plan = clean(syncfree_plan)
    exb = plan.ex_boundary.copy()
    real = np.nonzero(exb != plan.bs.nb)[0]
    assert real.size >= 2
    exb[real[1]] = exb[real[0]]
    report = verify_plan(mutate(plan, ex_boundary=exb), level="basic")
    bad = report.by_rule("hb.exchange.once")
    assert bad and int(exb[real[0]]) in bad[0].rows


def test_mutation_bucket_id_out_of_range(chain_plan):
    """A corrupt bucket id is flagged (not crashed on) by the lint."""
    plan = clean(chain_plan)
    lb = plan.lvl_bucket.copy()
    lb[0] = len(plan.buckets) + 3
    report = verify_plan(mutate(plan, lvl_bucket=lb), level="contracts")
    bad = report.by_rule("kc.buckets.fit")
    assert bad and bad[0].level == 0


def test_mutation_poisoned_pad_tile(chain_plan):
    """A non-zero pad tile would inject garbage through every pad update."""
    plan = clean(chain_plan)
    tiles = plan.tiles.copy()
    tiles[0, -1] = 1.0
    report = verify_plan(mutate(plan, tiles=tiles), level="contracts")
    assert any("zero tile" in f.message
               for f in report.by_rule("kc.pad.inert"))


# -----------------------------------------------------------------------
# dagpart merged supersteps (ISSUE 8): legal merges verify clean, illegal
# merges are caught with the exact happens-before / contract rule
# -----------------------------------------------------------------------


def merge_everything(bs, part, **_kw):
    """An illegal merge pass: collapse the whole level range into ONE
    superstep, ignoring where every remote source actually solves."""
    return np.array([0, int(bs.block_level.max()) + 1], dtype=np.int32)


def test_dagpart_chain_collapses_supersteps():
    """The acceptance headline: a pure chain merges >= 2x fewer supersteps
    than levelset, and the merged plan still verifies strict."""
    a = suite.chain(160)
    plan = build_plan(a, 1, SolverConfig(block_size=8, sched="dagpart"))
    assert verify_plan(plan, level="strict").passed
    ds = dispatch_stats(plan)
    assert ds["supersteps_levelset"] == plan.n_levels
    assert ds["superstep_reduction"] >= 2.0
    assert ds["supersteps"] < ds["supersteps_levelset"]
    assert ds["schedule_table_bytes"] > 0


def test_dagpart_clean_plan_verifies_strict(dagpart_plan):
    """The multi-device merged plan with a real cut is itself clean (the
    uncorrupted baseline for the illegal-merge mutations below)."""
    plan = clean(dagpart_plan)
    assert plan.step_off is not None
    report = verify_plan(plan, level="strict")
    assert "hb.exchange.position" in report.rules_checked
    assert "kc.steps.partition" in report.rules_checked


def test_mutation_illegal_merge_zerocopy_strands_exchange():
    """Force-merging past a cross-device dependency hoists the exchange of a
    row whose remote update now lands in the same superstep —
    hb.exchange.position must call the contribution stranded."""
    a = suite.chain(160)
    cfg = SolverConfig(block_size=8, sched="dagpart", partition="taskpool")
    with mock.patch("repro.core.solver.merge_levels", merge_everything):
        plan = build_plan(a, 2, cfg)
    report = verify_plan(plan, level="strict")
    assert not report.passed
    bad = report.by_rule("hb.exchange.position")
    assert bad and any("stranded" in f.message for f in bad)


def test_mutation_illegal_merge_unified_dest_step():
    """Under unified comm the dense psum folds the cross-device delta only at
    superstep boundaries: an intra-step remote update passes the micro-level
    hb.upd.dest-after walk but must fail the superstep-granular
    hb.upd.dest-step rule."""
    a = suite.chain(160)
    cfg = SolverConfig(block_size=8, sched="dagpart", comm="unified",
                       partition="taskpool")
    with mock.patch("repro.core.solver.merge_levels", merge_everything):
        plan = build_plan(a, 2, cfg)
    report = verify_plan(plan, level="strict")
    assert not report.passed
    bad = report.by_rule("hb.upd.dest-step")
    assert bad and any("never arrives" in f.message for f in bad)
    # micro-level ordering is intact — only the step granularity is broken
    assert not report.by_rule("hb.upd.dest-after")


def test_mutation_corrupt_step_table(dagpart_plan):
    """A step table that no longer partitions [0, T] is flagged by the
    kernel-contract lint (kc.steps.partition), not crashed on."""
    plan = clean(dagpart_plan)
    T = plan.n_levels
    for corrupt in (np.array([0, 0, T], np.int32),     # not strictly increasing
                    np.array([1, T], np.int32),        # does not start at 0
                    np.array([0, T + 1], np.int32)):   # overshoots T
        report = verify_plan(mutate(plan, step_off=corrupt),
                             level="contracts")
        assert report.by_rule("kc.steps.partition"), corrupt


# -----------------------------------------------------------------------
# empty-cut regression (the violation the verifier surfaced, now fixed)
# -----------------------------------------------------------------------


def test_unified_empty_cut_schedules_no_communication():
    """Diagonal-only matrices have an empty cut under any partition: the
    unified plan must not schedule dense psums or per-level fused launches
    (hb.exchange.degenerate — the bug this PR's verifier caught)."""
    a = strategies.diagonal_matrix()
    plan = build_plan(a, 4, SolverConfig(block_size=8, comm="unified"))
    assert plan.n_boundary_rows == 0
    assert plan.comm_bytes_per_solve == 0
    assert len(fused_segments(plan)) == 1
    ds = dispatch_stats(plan)
    assert ds["fused_launches"] == 1 and ds["exchanges"] == 0
    assert verify_plan(plan, level="strict").passed


@pytest.mark.parametrize("sched", ["levelset", "dagpart", "syncfree"])
@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
def test_empty_cut_plans_verify_strict(sched, comm):
    """Every sched x comm combination over an empty cut is degeneracy-free."""
    a = strategies.diagonal_matrix()
    plan = build_plan(a, 4, SolverConfig(block_size=8, sched=sched, comm=comm))
    report = verify_plan(plan, level="strict")
    assert report.passed, "\n".join(str(f) for f in report.findings)


def test_unified_empty_cut_solve_matches_reference():
    """The degenerate-path executor (no psums, single launch) still solves
    correctly on one device."""
    a = strategies.diagonal_matrix()
    b = np.arange(1.0, a.n + 1)
    plan = build_plan(a, 1, SolverConfig(block_size=8, comm="unified"))
    x = DistributedSolver(plan, strategies.mesh1()).solve(b)
    np.testing.assert_allclose(np.asarray(x), b / 2.0, rtol=1e-6)


# -----------------------------------------------------------------------
# clean-plan coverage: builders x modes verify at the strictest level
# -----------------------------------------------------------------------


@pytest.mark.parametrize("sched", ["levelset", "dagpart", "syncfree"])
@pytest.mark.parametrize("comm", ["zerocopy", "unified"])
@pytest.mark.parametrize("transpose", [False, True])
def test_builder_plans_verify_strict(sched, comm, transpose):
    a = suite.random_levelled(300, 8, 4.0, seed=7, locality=0.8)
    for D in (1, 4):
        plan = build_plan(a, D, SolverConfig(
            block_size=8, sched=sched, comm=comm, partition="malleable"),
            transpose=transpose)
        report = verify_plan(plan, level="strict")
        assert report.passed, "\n".join(str(f) for f in report.findings)
        assert len(report.rules_checked) >= 10


def test_sweep_module_is_green():
    """The CI gate itself: the full matrix x mode grid verifies clean."""
    from repro.verify.sweep import run_sweep

    out = io.StringIO()
    assert run_sweep(level="strict", out=out) == 0
    assert "PASS" in out.getvalue()


# -----------------------------------------------------------------------
# report + wiring
# -----------------------------------------------------------------------


def test_report_shape_and_serialization(chain_plan):
    report = verify_plan(chain_plan, level="strict")
    assert isinstance(report, VerificationReport)
    assert report.level == "strict" and report.passed
    assert "hb.upd.src-before" in report.rules_checked
    assert "kc.stream.slices" in report.rules_checked
    d = report.to_dict()
    assert d["passed"] and d["plan"]["sched"] == "levelset"
    assert d["findings"] == []
    assert report.raise_if_failed() is report
    assert "PASS" in report.summary()


def test_report_raise_carries_findings(chain_plan):
    sr = chain_plan.solve_rows.copy()
    sr[0, 0] = -1  # row 0 is never solved
    bad = mutate(chain_plan, solve_rows=sr)
    with pytest.raises(PlanVerificationError) as ei:
        verify_plan(bad, level="basic").raise_if_failed()
    assert ei.value.report.by_rule("hb.solve.once")
    assert "hb.solve.once" in str(ei.value)
    f = ei.value.report.by_rule("hb.solve.once")[0].to_dict()
    assert f["rows"] == [0] and f["severity"] == "error"


def test_basic_level_skips_contract_lint(chain_plan):
    report = verify_plan(chain_plan, level="basic")
    assert not any(r.startswith("kc.") for r in report.rules_checked)
    with pytest.raises(ValueError, match="invalid verify level"):
        verify_plan(chain_plan, level="paranoid")


def test_env_verify_level(monkeypatch):
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    assert env_verify_level() is None
    assert env_verify_level(default="basic") == "basic"
    for raw, want in (("", None), ("0", None), ("off", None),
                      ("none", None), ("false", None),
                      ("basic", "basic"), ("contracts", "contracts"),
                      ("strict", "strict"), ("1", "strict"),
                      ("yes", "strict"), ("STRICT", "strict")):
        monkeypatch.setenv("REPRO_VERIFY", raw)
        assert env_verify_level(default="basic") == want, raw


def test_build_plan_verify_optin(monkeypatch):
    from repro.obs.metrics import get_registry

    a = suite.chain(40)
    runs = get_registry().counter("verify.runs")
    before = runs.value
    build_plan(a, 1, SolverConfig(block_size=8), verify="strict")
    assert runs.value == before + 1
    # env opt-in reaches build_plan without the kwarg
    monkeypatch.setenv("REPRO_VERIFY", "strict")
    build_plan(a, 1, SolverConfig(block_size=8))
    assert runs.value == before + 2
    monkeypatch.delenv("REPRO_VERIFY")
    build_plan(a, 1, SolverConfig(block_size=8))
    assert runs.value == before + 2  # off by default


def test_plan_options_verify_field():
    from repro.api import PlanOptions

    assert PlanOptions(verify="strict").verify == "strict"
    assert PlanOptions().verify is None
    with pytest.raises(ValueError, match="invalid verify"):
        PlanOptions(verify="paranoid")


def test_verify_emits_trace_span(chain_plan):
    from repro.obs.trace import trace_to

    with trace_to() as tracer:
        verify_plan(chain_plan, level="contracts")
        records = tracer.export()
    spans = [r for r in records
             if r.get("type") == "span" and r["name"] == "sptrsv.verify"]
    assert spans and spans[0]["attrs"]["passed"] is True
    assert spans[0]["attrs"]["n_errors"] == 0
